//! Auto-tuner: search GEMM tile parameters (`mr` register rows and the
//! `kc`/`rc` cache-panel sizes shared by both conv drivers), SIMD kernel
//! variant, per-layer worker count and the fused-vs-materialized execution
//! path per layer shape on the actual machine — the paper's "all models
//! are tuned to their best configurations, e.g. the best tiling size,
//! unrolling size".
//!
//! The winning configuration is persisted as a JSON tuning database
//! ([`TuneDb`]) that `NativeEngine` loads at build time (path from
//! `RT3D_TUNE_DB`, falling back to `<crate>/tune_db.json`), so a tuned
//! deployment keeps its per-layer config across restarts.

use crate::codegen::{
    quantize_span, CompiledConv, ConvKind, GemmTile, KernelArch, Precision,
};
use crate::executors::{self, AccSlabs};
use crate::tensor::{Mat, MatI8, Tensor5};
use crate::util::error::Context;
use crate::util::json::Json;
use crate::util::pool::ThreadPool;
use std::time::Instant;

/// Candidate tile grid, mr-major (the tuner repacks once per mr step).
/// Small by design: the paper's tuner explores tiling and unrolling; we
/// search register rows x cache blocks.
pub fn candidates() -> Vec<GemmTile> {
    let mut v = Vec::new();
    for mr in [2usize, 4, 8] {
        for rc in [128usize, 256, 512, 1024] {
            for kc in [64usize, 128, 256, 512] {
                v.push(GemmTile { mr, rc, kc });
            }
        }
    }
    v
}

/// Time one conv execution with a given tile (median of `reps`).
/// Runs on the process-global pool so tuning reflects the `RT3D_THREADS`
/// the model will serve with; the tile, kernel and worker cap are
/// overridden on the call binding, never by cloning the plan's weights.
/// `tile.mr` must match the plan's packed layout for Dense/Filter kinds —
/// [`tune_conv`] repacks via `set_tile` before crossing an mr boundary.
pub fn time_conv(cc: &CompiledConv, x: &Tensor5, tile: GemmTile, reps: usize) -> f64 {
    debug_assert!(
        cc.packed.as_ref().map_or(true, |p| p.mr == tile.mr.max(1)),
        "tile.mr must match the packed panel height (call set_tile first)"
    );
    let g = cc.geom;
    let pt = executors::im2col_t(x, &g);
    let mut out = Mat::zeros(g.out_ch, pt.cols);
    let mut call = cc.bind(g.in_spatial);
    call.tile = tile;
    let pool = ThreadPool::global();
    let slabs = AccSlabs::global();
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            // run_conv_bound owns output init itself.
            let t0 = Instant::now();
            executors::run_conv_bound(&call, &pt, &mut out, pool, slabs);
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Time one conv end-to-end on either execution path — patch formation
/// *included* (unlike [`time_conv`], which times the GEMM alone), because
/// the fused path's whole point is folding patch formation into the
/// cache-resident blocks. Buffers are reused across reps so the timing
/// reflects the engine's steady state.
pub fn time_conv_path(cc: &CompiledConv, x: &Tensor5, fused: bool, reps: usize) -> f64 {
    let g = cc.geom;
    let pool = ThreadPool::global();
    let slabs = AccSlabs::global();
    let mut patches = Mat::zeros(0, 0);
    let mut out = Mat::zeros(g.out_ch, g.rows(x.dims[0]));
    let call = cc.bind(g.in_spatial);
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            if fused {
                executors::run_conv_fused(&call, x, &mut out, pool, slabs);
            } else {
                patches.reset(g.cols(), g.rows(x.dims[0]));
                executors::im2col_t_into_with(x, &g, &mut patches, pool);
                executors::run_conv_bound(&call, &patches, &mut out, pool, slabs);
            }
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// [`time_conv`] at a chosen precision. Int8 times the widening-kernel
/// GEMM over a pre-quantized patch matrix — quantization excluded, like
/// `time_conv` times the f32 GEMM alone. Falls back to f32 timing when
/// the plan carries no quantized sidecar.
pub fn time_conv_prec(
    cc: &CompiledConv,
    x: &Tensor5,
    tile: GemmTile,
    reps: usize,
    precision: Precision,
) -> f64 {
    if precision == Precision::F32 || cc.int8.is_none() {
        return time_conv(cc, x, tile, reps);
    }
    debug_assert!(
        cc.packed.as_ref().map_or(true, |p| p.mr == tile.mr.max(1)),
        "tile.mr must match the packed panel height (call set_tile first)"
    );
    let g = cc.geom;
    let pt = executors::im2col_t(x, &g);
    let plan = cc.int8.as_ref().unwrap();
    let in_scale = executors::layer_input_scale(plan, x);
    let n = pt.rows * pt.cols;
    let mut qpt = MatI8::zeros(pt.rows, pt.cols);
    quantize_span(&pt.data[..n], 1.0 / in_scale, &mut qpt.data[..n]);
    let mut out = Mat::zeros(g.out_ch, pt.cols);
    let mut call = cc.bind_exec(g.in_spatial, None, None, Precision::Int8);
    call.tile = tile;
    let pool = ThreadPool::global();
    let slabs = AccSlabs::global();
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            executors::run_conv_bound_i8(
                &call, in_scale, &qpt, &mut out, pool, slabs,
            );
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// [`time_conv_path`] at a chosen precision. Int8 times the full int8
/// pipeline per rep — patch formation, activation quantization and the
/// widening GEMM — since that is what the engine executes per layer call.
pub fn time_conv_path_prec(
    cc: &CompiledConv,
    x: &Tensor5,
    fused: bool,
    reps: usize,
    precision: Precision,
) -> f64 {
    if precision == Precision::F32 || cc.int8.is_none() {
        return time_conv_path(cc, x, fused, reps);
    }
    let g = cc.geom;
    let pool = ThreadPool::global();
    let slabs = AccSlabs::global();
    let plan = cc.int8.as_ref().unwrap();
    let in_scale = executors::layer_input_scale(plan, x);
    let mut patches = Mat::zeros(0, 0);
    let mut qpatches = MatI8::zeros(0, 0);
    let mut out = Mat::zeros(g.out_ch, g.rows(x.dims[0]));
    let call = cc.bind_exec(g.in_spatial, None, None, Precision::Int8);
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            if fused {
                executors::run_conv_fused_i8(
                    &call, in_scale, x, &mut out, pool, slabs,
                );
            } else {
                patches.reset(g.cols(), g.rows(x.dims[0]));
                executors::im2col_t_into_with(x, &g, &mut patches, pool);
                let n = patches.rows * patches.cols;
                qpatches.reset(patches.rows, patches.cols);
                quantize_span(
                    &patches.data[..n],
                    1.0 / in_scale,
                    &mut qpatches.data[..n],
                );
                executors::run_conv_bound_i8(
                    &call, in_scale, &qpatches, &mut out, pool, slabs,
                );
            }
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Result of tuning one layer.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub name: String,
    pub best: GemmTile,
    /// Tuned kernel override (`None` = the auto-detected ISA won).
    pub kernel: Option<KernelArch>,
    /// Tuned worker cap (0 = every pool worker).
    pub threads: usize,
    /// Measured execution-path choice (fused implicit GEMM vs
    /// materialized im2col) at the winning config.
    pub fused: bool,
    pub best_s: f64,
    pub default_s: f64,
}

impl TuneReport {
    pub fn speedup(&self) -> f64 {
        self.default_s / self.best_s
    }
}

/// Tune a compiled conv in place (tile grid, then kernel variant, then
/// worker cap, then fused-vs-materialized — a coordinate descent over the
/// four config axes); returns the report. Tunes the f32 path; see
/// [`tune_conv_prec`] for the precision axis.
pub fn tune_conv(cc: &mut CompiledConv, reps: usize) -> TuneReport {
    tune_conv_prec(cc, reps, Precision::F32)
}

/// [`tune_conv`] at a chosen precision: the identical coordinate descent,
/// timed through that precision's drivers, so the int8 path gets its own
/// winning tile/kernel/cap/fused choice (persist with
/// [`TuneDb::record_prec`]).
pub fn tune_conv_prec(
    cc: &mut CompiledConv,
    reps: usize,
    precision: Precision,
) -> TuneReport {
    let x = Tensor5::random(
        [
            1,
            cc.geom.in_ch,
            cc.geom.in_spatial[0],
            cc.geom.in_spatial[1],
            cc.geom.in_spatial[2],
        ],
        7,
    );
    cc.set_tile(GemmTile::default());
    cc.kernel = None;
    cc.threads = 0;
    cc.fused = None;
    let default_s = time_conv_prec(cc, &x, GemmTile::default(), reps, precision);
    let mut best = GemmTile::default();
    let mut best_s = default_s;
    // --- tile grid (repack once per mr step) ---------------------------
    for t in candidates() {
        // mr only changes the dense packing; sparse panels use their own
        // per-group walk, so skip the redundant mr sweep there.
        if matches!(
            cc.kind,
            ConvKind::Kgs { .. }
                | ConvKind::Vanilla { .. }
                | ConvKind::Pattern { .. }
                | ConvKind::BlockPunched { .. }
        ) && t.mr != GemmTile::default().mr
        {
            continue;
        }
        if t.mr != cc.tile.mr {
            cc.set_tile(GemmTile { mr: t.mr, ..cc.tile });
        }
        let s = time_conv_prec(cc, &x, t, reps, precision);
        if s < best_s {
            best_s = s;
            best = t;
        }
    }
    cc.set_tile(best);
    // --- kernel variant (detected ISA vs scalar fallback) --------------
    let active = KernelArch::active();
    if active != KernelArch::Scalar {
        cc.kernel = Some(KernelArch::Scalar);
        let s = time_conv_prec(cc, &x, best, reps, precision);
        if s < best_s {
            best_s = s;
        } else {
            cc.kernel = None;
        }
    }
    // --- per-layer worker cap (small layers often prefer fewer) --------
    let full = ThreadPool::global().threads();
    let mut best_cap = 0usize; // 0 = uncapped
    for cap in [1usize, 2, 4] {
        if cap >= full {
            break;
        }
        cc.threads = cap;
        let s = time_conv_prec(cc, &x, best, reps, precision);
        if s < best_s {
            best_s = s;
            best_cap = cap;
        } else {
            break;
        }
    }
    cc.threads = best_cap;
    // --- execution path: fused implicit GEMM vs materialized im2col ----
    // Timed end-to-end (patch formation included), since that is
    // precisely the cost the fused path restructures. The fused driver
    // has its own cache sweet spot — its per-worker panel is (kc, rc)-
    // sized — so the cache-block axes are re-searched on the fused path
    // rather than inheriting the materialized winner (mr stays fixed: it
    // only affects the weight packing, which both drivers share). The
    // path choice never changes output bits — only scratch shape and
    // memory traffic — so it is free to flip per machine.
    let t_mat = time_conv_path_prec(cc, &x, false, reps, precision);
    let mut t_fus = time_conv_path_prec(cc, &x, true, reps, precision);
    let mut fus_tile = best;
    for rc in [128usize, 256, 512] {
        for kc in [64usize, 128, 256] {
            let t = GemmTile { rc, kc, ..best };
            if t == best {
                continue;
            }
            cc.set_tile(t); // same mr -> no repack
            let s = time_conv_path_prec(cc, &x, true, reps, precision);
            if s < t_fus {
                t_fus = s;
                fus_tile = t;
            }
        }
    }
    let fused = t_fus < t_mat;
    let final_tile = if fused { fus_tile } else { best };
    cc.set_tile(final_tile);
    cc.fused = Some(fused);
    TuneReport {
        name: cc.name.clone(),
        best: final_tile,
        kernel: cc.kernel,
        threads: cc.threads,
        fused,
        best_s,
        default_s,
    }
}

/// Tune every conv of a compiled model (in place).
pub fn tune_model(convs: &mut [CompiledConv], reps: usize) -> Vec<TuneReport> {
    convs.iter_mut().map(|c| tune_conv(c, reps)).collect()
}

/// Tune every conv and collect the winning configs into a database ready
/// to persist with [`TuneDb::save`].
pub fn tune_model_db(convs: &mut [CompiledConv], reps: usize) -> (Vec<TuneReport>, TuneDb) {
    let reports = tune_model(convs, reps);
    let mut db = TuneDb::default();
    for cc in convs.iter() {
        db.record(cc);
    }
    (reports, db)
}

/// [`tune_model_db`] at a chosen precision, recording the winners under
/// that precision's database keys — run once per precision over the same
/// plans to grow one database carrying both tunings.
pub fn tune_model_db_prec(
    convs: &mut [CompiledConv],
    reps: usize,
    precision: Precision,
) -> (Vec<TuneReport>, TuneDb) {
    let reports = convs
        .iter_mut()
        .map(|c| tune_conv_prec(c, reps, precision))
        .collect();
    let mut db = TuneDb::default();
    for cc in convs.iter() {
        db.record_prec(cc, precision);
    }
    (reports, db)
}

/// One persisted per-layer configuration.
#[derive(Debug, Clone, Copy)]
pub struct TuneEntry {
    pub tile: GemmTile,
    /// `None` = auto (detected ISA).
    pub kernel: Option<KernelArch>,
    /// 0 = every pool worker.
    pub threads: usize,
    /// Measured fused/materialized choice; `None` = auto (the footprint
    /// heuristic — also what pre-fused databases decode to).
    pub fused: Option<bool>,
}

/// Persisted tuning database: layer key -> winning config. The key folds
/// in the layer name, plan kind and GEMM shape so a retuned or reshaped
/// model never picks up a stale entry.
#[derive(Debug, Clone, Default)]
pub struct TuneDb {
    pub entries: std::collections::HashMap<String, TuneEntry>,
}

impl TuneDb {
    pub fn key(cc: &CompiledConv) -> String {
        let kind = match &cc.kind {
            ConvKind::Dense { .. } => "dense",
            ConvKind::Kgs { .. } => "kgs",
            ConvKind::Vanilla { .. } => "vanilla",
            ConvKind::Pattern { .. } => "pattern",
            ConvKind::BlockPunched { .. } => "block_punched",
            ConvKind::Filter { .. } => "filter",
        };
        format!(
            "{}|{kind}|m{}k{}r{}",
            cc.name,
            cc.geom.out_ch,
            cc.geom.cols(),
            cc.geom.rows(1)
        )
    }

    /// [`Self::key`] at a precision — the database's precision axis. Int8
    /// entries append `|int8`; f32 keys stay unsuffixed so pre-int8
    /// databases keep matching unchanged.
    pub fn key_prec(cc: &CompiledConv, precision: Precision) -> String {
        match precision {
            Precision::F32 => Self::key(cc),
            Precision::Int8 => format!("{}|int8", Self::key(cc)),
        }
    }

    pub fn record(&mut self, cc: &CompiledConv) {
        self.record_prec(cc, Precision::F32);
    }

    /// Record the plan's current config under the given precision's key.
    pub fn record_prec(&mut self, cc: &CompiledConv, precision: Precision) {
        self.entries.insert(
            Self::key_prec(cc, precision),
            TuneEntry {
                tile: cc.tile,
                kernel: cc.kernel,
                threads: cc.threads,
                fused: cc.fused,
            },
        );
    }

    /// Apply a stored config to a freshly compiled plan (repacking for the
    /// stored mr). A kernel override the running machine cannot execute
    /// (e.g. a db tuned on an AVX2 host, applied on one without) falls
    /// back to auto — `bind()` must never resolve to an unsupported ISA,
    /// that would be UB in the `target_feature` kernels. Returns whether
    /// an entry matched.
    pub fn apply(&self, cc: &mut CompiledConv) -> bool {
        self.apply_prec(cc, Precision::F32)
    }

    /// [`Self::apply`] preferring the given precision's entry. An int8
    /// engine on a database without int8 entries falls back to the f32
    /// tuning (better than stock defaults: the cache-blocking pressure is
    /// similar), so older databases keep working under `RT3D_PRECISION`.
    pub fn apply_prec(&self, cc: &mut CompiledConv, precision: Precision) -> bool {
        let entry = self
            .entries
            .get(&Self::key_prec(cc, precision))
            .or_else(|| self.entries.get(&Self::key(cc)));
        match entry {
            Some(e) => {
                cc.set_tile(e.tile);
                cc.kernel = e.kernel.filter(|k| k.supported());
                if cc.kernel != e.kernel {
                    eprintln!(
                        "tune db: kernel {:?} for {} unsupported here; using auto",
                        e.kernel.map(|k| k.name()),
                        cc.name
                    );
                }
                cc.threads = e.threads;
                cc.fused = e.fused;
                true
            }
            None => false,
        }
    }

    /// Default database location: `RT3D_TUNE_DB` when set, else
    /// `<crate>/tune_db.json` next to the manifest. An explicit
    /// `EngineOptions::tune_db` path outranks both (resolved by the
    /// engine builder, not here).
    pub fn default_path() -> std::path::PathBuf {
        crate::util::env::tune_db_path()
            .unwrap_or_else(crate::util::env::default_tune_db_path)
    }

    /// Load the default database if one exists (quietly `None` otherwise —
    /// an untuned machine runs on defaults).
    pub fn load_default() -> Option<TuneDb> {
        Self::load_at(&Self::default_path())
    }

    /// Load the database at `path` if one exists there (quietly `None`
    /// when missing; unreadable databases are reported and ignored, so a
    /// stale file can never brick an engine build).
    pub fn load_at(path: &std::path::Path) -> Option<TuneDb> {
        if !path.exists() {
            return None;
        }
        match Self::load(path) {
            Ok(db) => Some(db),
            Err(e) => {
                eprintln!("ignoring unreadable tune db {}: {e}", path.display());
                None
            }
        }
    }

    pub fn load(path: &std::path::Path) -> crate::util::error::Result<TuneDb> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = Json::parse(&text)?;
        let mut db = TuneDb::default();
        for e in doc.req("entries")?.as_arr()? {
            let key = e.req("key")?.as_str()?.to_string();
            let tile = GemmTile {
                mr: e.req("mr")?.as_usize()?,
                rc: e.req("rc")?.as_usize()?,
                kc: e.req("kc")?.as_usize()?,
            };
            let kernel = match e.req("kernel")?.as_str()? {
                "auto" => None,
                name => match KernelArch::parse(name) {
                    Some(k) => Some(k),
                    None => {
                        eprintln!("tune db: unknown kernel {name:?}; using auto");
                        None
                    }
                },
            };
            let threads = e.req("threads")?.as_usize()?;
            // Optional for databases written before the fused path existed.
            let fused = match e.get("fused").map(|f| f.as_str()) {
                Some(Ok("fused")) => Some(true),
                Some(Ok("materialized")) => Some(false),
                _ => None,
            };
            db.entries.insert(key, TuneEntry { tile, kernel, threads, fused });
        }
        Ok(db)
    }

    pub fn save(&self, path: &std::path::Path) -> crate::util::error::Result<()> {
        // Keys carry manifest layer names verbatim — escape so a name with
        // a quote/backslash cannot produce an unloadable database.
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let mut keys: Vec<&String> = self.entries.keys().collect();
        keys.sort();
        let mut json = String::from("{\n  \"version\": 1,\n  \"entries\": [\n");
        for (i, key) in keys.iter().enumerate() {
            let e = &self.entries[*key];
            json.push_str(&format!(
                "    {{\"key\": \"{}\", \"mr\": {}, \"rc\": {}, \"kc\": {}, \"kernel\": \"{}\", \"threads\": {}, \"fused\": \"{}\"}}{}\n",
                esc(key),
                e.tile.mr,
                e.tile.rc,
                e.tile.kc,
                e.kernel.map_or("auto", |k| k.name()),
                e.threads,
                match e.fused {
                    Some(true) => "fused",
                    Some(false) => "materialized",
                    None => "auto",
                },
                if i + 1 < keys.len() { "," } else { "" }
            ));
        }
        json.push_str("  ]\n}\n");
        std::fs::write(path, json)
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

/// Group-size sweep used by E7 (`benches/group_size.rs` + `tune_groups`
/// example): time a synthesized KGS layer at a given (g_m, g_n) and keep
/// fraction, returning (seconds, achieved FLOPs fraction).
pub fn time_group_size(
    m: usize,
    c: usize,
    spatial: [usize; 3],
    g_m: usize,
    g_n: usize,
    keep_frac: f64,
    reps: usize,
) -> (f64, f64) {
    use crate::codegen::{compile_conv_sparse, Scheme};
    use crate::model::{TensorRef, WeightRefs};

    let kernel = [3usize, 3, 3];
    let ks: usize = kernel.iter().product();
    let pp = m.div_ceil(g_m);
    let qq = c.div_ceil(g_n);
    // Deterministic mask: keep ~keep_frac of locations per group.
    let keep = ((ks as f64) * keep_frac).round().max(1.0) as usize;
    let mut mask = vec![false; pp * qq * ks];
    for g in 0..pp * qq {
        for loc in 0..keep.min(ks) {
            // Spread kept taps deterministically.
            mask[g * ks + (loc * 7 + g) % ks] = true;
        }
    }
    let dummy = TensorRef { offset: 0, shape: vec![], dtype: "f32".into() };
    let layer = crate::model::ConvLayer {
        name: format!("sweep_{g_m}x{g_n}"),
        in_ch: c,
        out_ch: m,
        kernel,
        stride: [1, 1, 1],
        padding: [1, 1, 1],
        relu: true,
        weights: WeightRefs { w: dummy.clone(), b: dummy },
        weights_sparse: None,
        unit_mask: None,
        quant: None,
    };
    let geom = crate::tensor::Conv3dGeometry {
        in_ch: c,
        out_ch: m,
        kernel,
        stride: [1, 1, 1],
        padding: [1, 1, 1],
        in_spatial: spatial,
    };
    let w = Tensor5::random([m, c, 3, 3, 3], 3).data;
    let cc = compile_conv_sparse(
        &layer,
        &geom,
        &w,
        vec![0.0; m],
        &mask,
        Scheme::Kgs,
        g_m,
        g_n,
    );
    let x = Tensor5::random([1, c, spatial[0], spatial[1], spatial[2]], 4);
    let secs = time_conv(&cc, &x, cc.tile, reps);
    (secs, cc.flops as f64 / geom.flops(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::GemmTile;

    #[test]
    fn candidates_nonempty_and_unique() {
        let c = candidates();
        assert!(c.len() >= 16);
        let mut seen = std::collections::HashSet::new();
        for t in &c {
            assert!(seen.insert((t.mr, t.rc, t.kc)));
        }
    }

    #[test]
    fn group_sweep_flops_fraction() {
        let (_, frac) = time_group_size(16, 16, [4, 8, 8], 4, 4, 0.33, 1);
        assert!((frac - 9.0 / 27.0).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn default_tile_sane() {
        let t = GemmTile::default();
        assert!(t.mr >= 1 && t.rc >= 1 && t.kc >= 1);
    }

    #[test]
    fn tune_db_round_trips_through_json() {
        let mut db = TuneDb::default();
        db.entries.insert(
            "conv1|dense|m16k216r8192".into(),
            TuneEntry {
                tile: GemmTile { mr: 8, rc: 256, kc: 128 },
                kernel: Some(KernelArch::Scalar),
                threads: 2,
                fused: Some(true),
            },
        );
        db.entries.insert(
            "conv2|kgs|m32k864r2048".into(),
            TuneEntry {
                tile: GemmTile::default(),
                kernel: None,
                threads: 0,
                fused: None,
            },
        );
        let dir = std::env::temp_dir();
        let path = dir.join(format!("rt3d_tune_db_test_{}.json", std::process::id()));
        db.save(&path).unwrap();
        let loaded = TuneDb::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.entries.len(), 2);
        let e = &loaded.entries["conv1|dense|m16k216r8192"];
        assert_eq!(e.tile, GemmTile { mr: 8, rc: 256, kc: 128 });
        assert_eq!(e.kernel, Some(KernelArch::Scalar));
        assert_eq!(e.threads, 2);
        assert_eq!(e.fused, Some(true));
        let e2 = &loaded.entries["conv2|kgs|m32k864r2048"];
        assert_eq!(e2.kernel, None);
        assert_eq!(e2.threads, 0);
        assert_eq!(e2.fused, None);
    }

    #[test]
    fn tune_db_pre_fused_documents_decode_to_auto() {
        // Databases written before the fused axis existed have no "fused"
        // key; they must load with fused = auto, not fail.
        let json = "{\n  \"version\": 1,\n  \"entries\": [\n    {\"key\": \"old|dense|m4k8r64\", \"mr\": 4, \"rc\": 512, \"kc\": 256, \"kernel\": \"auto\", \"threads\": 0}\n  ]\n}\n";
        let dir = std::env::temp_dir();
        let path =
            dir.join(format!("rt3d_tune_db_prefused_{}.json", std::process::id()));
        std::fs::write(&path, json).unwrap();
        let loaded = TuneDb::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.entries["old|dense|m4k8r64"].fused, None);
    }

    #[test]
    fn tune_db_applies_and_repacks() {
        use crate::codegen::compile_conv_dense;
        use crate::model::{TensorRef, WeightRefs};
        let dummy = TensorRef { offset: 0, shape: vec![], dtype: "f32".into() };
        let layer = crate::model::ConvLayer {
            name: "t".into(),
            in_ch: 4,
            out_ch: 6,
            kernel: [1, 1, 1],
            stride: [1, 1, 1],
            padding: [0, 0, 0],
            relu: false,
            weights: WeightRefs { w: dummy.clone(), b: dummy },
            weights_sparse: None,
            unit_mask: None,
            quant: None,
        };
        let geom = crate::tensor::Conv3dGeometry {
            in_ch: 4,
            out_ch: 6,
            kernel: [1, 1, 1],
            stride: [1, 1, 1],
            padding: [0, 0, 0],
            in_spatial: [2, 2, 2],
        };
        let w = vec![0.5f32; 6 * 4];
        let mut cc = compile_conv_dense(&layer, &geom, &w, vec![0.0; 6]);
        let mut tuned = cc.clone();
        tuned.set_tile(GemmTile { mr: 3, rc: 64, kc: 32 });
        tuned.threads = 2;
        tuned.fused = Some(true);
        let mut db = TuneDb::default();
        db.record(&tuned);
        assert!(db.apply(&mut cc), "same key must match");
        assert_eq!(cc.tile, GemmTile { mr: 3, rc: 64, kc: 32 });
        assert_eq!(cc.threads, 2);
        assert_eq!(cc.fused, Some(true), "apply must carry the fused flag");
        assert_eq!(cc.packed.as_ref().unwrap().mr, 3, "apply must repack");
    }

    #[test]
    fn tune_db_precision_axis_suffixes_and_falls_back() {
        use crate::codegen::compile_conv_dense;
        use crate::model::{TensorRef, WeightRefs};
        let dummy = TensorRef { offset: 0, shape: vec![], dtype: "f32".into() };
        let layer = crate::model::ConvLayer {
            name: "q".into(),
            in_ch: 4,
            out_ch: 6,
            kernel: [1, 1, 1],
            stride: [1, 1, 1],
            padding: [0, 0, 0],
            relu: false,
            weights: WeightRefs { w: dummy.clone(), b: dummy },
            weights_sparse: None,
            unit_mask: None,
            quant: None,
        };
        let geom = crate::tensor::Conv3dGeometry {
            in_ch: 4,
            out_ch: 6,
            kernel: [1, 1, 1],
            stride: [1, 1, 1],
            padding: [0, 0, 0],
            in_spatial: [2, 2, 2],
        };
        let w = vec![0.25f32; 6 * 4];
        let mut cc = compile_conv_dense(&layer, &geom, &w, vec![0.0; 6]);
        assert_eq!(
            TuneDb::key_prec(&cc, Precision::Int8),
            format!("{}|int8", TuneDb::key(&cc))
        );
        // A database with only an f32 entry still tunes an int8 engine
        // (fallback), and a dedicated int8 entry wins once present.
        let mut f32_tuned = cc.clone();
        f32_tuned.set_tile(GemmTile { mr: 2, rc: 64, kc: 32 });
        let mut db = TuneDb::default();
        db.record(&f32_tuned);
        assert!(db.apply_prec(&mut cc, Precision::Int8), "falls back to f32");
        assert_eq!(cc.tile, GemmTile { mr: 2, rc: 64, kc: 32 });
        let mut i8_tuned = cc.clone();
        i8_tuned.set_tile(GemmTile { mr: 3, rc: 128, kc: 64 });
        i8_tuned.threads = 1;
        db.record_prec(&i8_tuned, Precision::Int8);
        assert!(db.apply_prec(&mut cc, Precision::Int8));
        assert_eq!(cc.tile, GemmTile { mr: 3, rc: 128, kc: 64 });
        assert_eq!(cc.threads, 1);
        // The f32 view of the same database is untouched by the int8 entry.
        let mut cc2 = compile_conv_dense(&layer, &geom, &w, vec![0.0; 6]);
        assert!(db.apply(&mut cc2));
        assert_eq!(cc2.tile, GemmTile { mr: 2, rc: 64, kc: 32 });
    }
}

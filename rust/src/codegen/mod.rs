//! Compiler-assisted code generation (the paper's §5.2 system contribution).
//!
//! Given a conv layer's weights and a structured-sparsity unit mask, this
//! module performs what RT3D's compiler does on the phone:
//!
//! * **weight layout reorganization** — compact the weight matrix so the
//!   remaining computation is a set of *smaller dense* GEMM panels
//!   ([`CompiledConv`]): KGS keeps per-group column lists, Vanilla keeps
//!   per-filter-group channel-group lists, Pattern keeps one fixed gather
//!   schedule per filter (PatDNN dictionary patterns), BlockPunched keeps
//!   one shared kept-K-column map per filter block (PCONV/GRIM punched
//!   holes), Filter keeps surviving rows;
//! * **computation regularization** — padding-free nonuniform group sizes
//!   are supported (unlike the HLO path which pads to the max group width);
//! * **configuration tuning** — [`tuner`] searches tile/register-block
//!   parameters per layer shape on the actual machine, mirroring the
//!   paper's "all models are tuned to their best configurations".

pub mod plan;
pub mod tuner;

pub use plan::{
    absmax, quant_scale, quantize_span, CompiledConv, ConvCall, ConvKind,
    FuseMode, GemmTile, GroupI8, Int8Plan, KernelArch, KgsGroup, PackedDense,
    PackedDenseI8, PanelSchedule, Precision, FUSE_PATCH_BYTES,
};

use crate::model::{ConvLayer, Model};
use crate::tensor::Conv3dGeometry;

/// Which sparsity scheme a unit mask encodes (from the manifest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    Filter,
    Vanilla,
    Kgs,
    /// Pattern-based kernel sparsity (PatDNN): per-kernel element mask
    /// drawn from a small pattern dictionary.
    Pattern,
    /// Block-punched fine-grained sparsity (PCONV/GRIM): per-block kept
    /// K-column map shared by every kernel in the block.
    BlockPunched,
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "filter" => Some(Scheme::Filter),
            "vanilla" => Some(Scheme::Vanilla),
            "kgs" => Some(Scheme::Kgs),
            "pattern" => Some(Scheme::Pattern),
            "block_punched" => Some(Scheme::BlockPunched),
            _ => None,
        }
    }
}

/// Compile every conv of a model: dense layers get dense plans; masked
/// layers get compacted sparse plans per the manifest's scheme.
pub fn compile_model(model: &Model, use_sparsity: bool) -> Vec<CompiledConv> {
    let scheme = model
        .manifest
        .sparsity
        .as_ref()
        .and_then(|s| Scheme::parse(&s.scheme));
    let (g_m, g_n) = model
        .manifest
        .sparsity
        .as_ref()
        .map(|s| (s.g_m, s.g_n))
        .unwrap_or((4, 4));
    model
        .conv_geometries()
        .into_iter()
        .map(|(layer, geom)| {
            // The sparse deployment carries its own (pruned + retrained)
            // weights; dense plans use the original dense weights.
            let refs = if use_sparsity {
                layer.weights_sparse.as_ref().unwrap_or(&layer.weights)
            } else {
                &layer.weights
            };
            let w = model.pool.f32(&refs.w);
            let b = model.pool.f32(&refs.b);
            let mut cc = match (&layer.unit_mask, scheme, use_sparsity) {
                (Some(mr), Some(sch), true) => {
                    let mask = model.pool.bool(mr);
                    compile_conv_sparse(layer, &geom, &w, b, &mask, sch, g_m, g_n)
                }
                _ => compile_conv_dense(layer, &geom, &w, b),
            };
            // Artifact-provided quantization scales (export.py) override
            // the compile-time recomputation so the deployed int8 path
            // matches the exporting quantizer exactly.
            if let Some(q) = &layer.quant {
                cc.apply_quant(&q.w_scales, q.in_scale);
            }
            cc
        })
        .collect()
}

/// Dense plan: weight matrix reshaped (M, K), K ordered (c, kd, kh, kw).
pub fn compile_conv_dense(
    layer: &ConvLayer,
    geom: &Conv3dGeometry,
    w: &[f32],
    bias: Vec<f32>,
) -> CompiledConv {
    let k = geom.cols();
    assert_eq!(w.len(), layer.out_ch * k);
    let mut cc = CompiledConv {
        name: layer.name.clone(),
        geom: *geom,
        relu: layer.relu,
        bias,
        kind: ConvKind::Dense { wmat: w.to_vec() },
        tile: GemmTile::default(),
        packed: None,
        sched: None,
        kernel: None,
        threads: 0,
        fused: None,
        int8: None,
        flops: geom.flops(1),
    };
    cc.finalize();
    cc
}

/// Sparse plan dispatch.
#[allow(clippy::too_many_arguments)]
pub fn compile_conv_sparse(
    layer: &ConvLayer,
    geom: &Conv3dGeometry,
    w: &[f32],
    bias: Vec<f32>,
    mask: &[bool],
    scheme: Scheme,
    g_m: usize,
    g_n: usize,
) -> CompiledConv {
    match scheme {
        Scheme::Kgs => compile_kgs(layer, geom, w, bias, mask, g_m, g_n),
        Scheme::Vanilla => compile_vanilla(layer, geom, w, bias, mask, g_m, g_n),
        Scheme::Filter => compile_filter(layer, geom, w, bias, mask),
        Scheme::Pattern => compile_pattern(layer, geom, w, bias, mask),
        Scheme::BlockPunched => {
            compile_block_punched(layer, geom, w, bias, mask, g_m)
        }
    }
}

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// KGS: per kernel group (p, q), keep the column list
/// `{ (c_local, loc) : mask[p][q][loc] }` and pack the surviving weights as
/// a (g_m_eff, cols) row-major panel. Nonuniform kept counts are kept
/// as-is — no padding (the "computation regularization" handled by the
/// executor's indirect column walk).
fn compile_kgs(
    layer: &ConvLayer,
    geom: &Conv3dGeometry,
    w: &[f32],
    bias: Vec<f32>,
    mask: &[bool],
    g_m: usize,
    g_n: usize,
) -> CompiledConv {
    let (m, c) = (layer.out_ch, layer.in_ch);
    let ks: usize = layer.kernel.iter().product();
    let (pp, qq) = (ceil_div(m, g_m), ceil_div(c, g_n));
    assert_eq!(mask.len(), pp * qq * ks, "kgs mask shape");
    let mut groups = Vec::with_capacity(pp * qq);
    let mut kept_weights = 0usize;
    for p in 0..pp {
        let m0 = p * g_m;
        let m_eff = g_m.min(m - m0);
        for q in 0..qq {
            let c0 = q * g_n;
            let n_eff = g_n.min(c - c0);
            // Kept locations for this group.
            let kept: Vec<usize> = (0..ks)
                .filter(|&loc| mask[(p * qq + q) * ks + loc])
                .collect();
            if kept.is_empty() {
                continue;
            }
            // Column order: (c_local major, kept-loc minor) — matches the
            // patchesT row index c*Ks + loc used by the executor.
            let mut cols = Vec::with_capacity(n_eff * kept.len());
            for jn in 0..n_eff {
                for &loc in &kept {
                    cols.push(((c0 + jn) * ks + loc) as u32);
                }
            }
            // Panel (m_eff rows x cols.len()) packed row-major.
            let mut panel = Vec::with_capacity(m_eff * cols.len());
            for im in 0..m_eff {
                let mrow = m0 + im;
                for jn in 0..n_eff {
                    let base = (mrow * c + (c0 + jn)) * ks;
                    for &loc in &kept {
                        panel.push(w[base + loc]);
                    }
                }
            }
            kept_weights += panel.len();
            groups.push(KgsGroup::new(m0, m_eff, cols, panel));
        }
    }
    let r = geom.rows(1);
    let mut cc = CompiledConv {
        name: layer.name.clone(),
        geom: *geom,
        relu: layer.relu,
        bias,
        flops: 2 * kept_weights * r,
        kind: ConvKind::Kgs { groups },
        tile: GemmTile::default(),
        packed: None,
        sched: None,
        kernel: None,
        threads: 0,
        fused: None,
        int8: None,
    };
    cc.finalize();
    cc
}

/// Vanilla: per filter-group row p, the kept channel groups with their
/// full (m_eff, n_eff*Ks) panels, flattened p-major (the schedule built by
/// `finalize` re-splits them into filter-group row buckets).
fn compile_vanilla(
    layer: &ConvLayer,
    geom: &Conv3dGeometry,
    w: &[f32],
    bias: Vec<f32>,
    mask: &[bool],
    g_m: usize,
    g_n: usize,
) -> CompiledConv {
    let (m, c) = (layer.out_ch, layer.in_ch);
    let ks: usize = layer.kernel.iter().product();
    let (pp, qq) = (ceil_div(m, g_m), ceil_div(c, g_n));
    assert_eq!(mask.len(), pp * qq, "vanilla mask shape");
    let mut groups = Vec::new();
    let mut kept_weights = 0usize;
    for p in 0..pp {
        let m0 = p * g_m;
        let m_eff = g_m.min(m - m0);
        for q in 0..qq {
            if !mask[p * qq + q] {
                continue;
            }
            let c0 = q * g_n;
            let n_eff = g_n.min(c - c0);
            let mut cols = Vec::with_capacity(n_eff * ks);
            for jn in 0..n_eff {
                for loc in 0..ks {
                    cols.push(((c0 + jn) * ks + loc) as u32);
                }
            }
            let mut panel = Vec::with_capacity(m_eff * cols.len());
            for im in 0..m_eff {
                let base = ((m0 + im) * c + c0) * ks;
                panel.extend_from_slice(&w[base..base + n_eff * ks]);
            }
            kept_weights += panel.len();
            groups.push(KgsGroup::new(m0, m_eff, cols, panel));
        }
    }
    let r = geom.rows(1);
    let mut cc = CompiledConv {
        name: layer.name.clone(),
        geom: *geom,
        relu: layer.relu,
        bias,
        flops: 2 * kept_weights * r,
        kind: ConvKind::Vanilla { groups },
        tile: GemmTile::default(),
        packed: None,
        sched: None,
        kernel: None,
        threads: 0,
        fused: None,
        int8: None,
    };
    cc.finalize();
    cc
}

/// Pattern (PatDNN): the mask is per weight element, `(M, C*Ks)` flat,
/// with every kernel `(m, c)` keeping one of a small dictionary of tap
/// patterns (the pruner guarantees the dictionary property; compilation
/// only needs the element mask). Each filter becomes one `m_eff == 1`
/// group whose `cols` are the kept `(c*Ks + loc)` patch rows in ascending
/// order — a fixed gather schedule per filter, zero per-element branching
/// in the inner loop. Filters with no kept taps emit no group (the
/// schedule's bias/ReLU epilogue still covers their rows).
fn compile_pattern(
    layer: &ConvLayer,
    geom: &Conv3dGeometry,
    w: &[f32],
    bias: Vec<f32>,
    mask: &[bool],
) -> CompiledConv {
    let (m, c) = (layer.out_ch, layer.in_ch);
    let ks: usize = layer.kernel.iter().product();
    let k = c * ks;
    assert_eq!(mask.len(), m * k, "pattern mask shape");
    let mut groups = Vec::with_capacity(m);
    let mut kept_weights = 0usize;
    for row in 0..m {
        // Ascending (c, loc) column order == ascending patchesT row index:
        // the fixed K accumulation order the parity invariant requires.
        let mut cols = Vec::new();
        let mut panel = Vec::new();
        for ki in 0..k {
            if mask[row * k + ki] {
                cols.push(ki as u32);
                panel.push(w[row * k + ki]);
            }
        }
        if cols.is_empty() {
            continue;
        }
        kept_weights += panel.len();
        groups.push(KgsGroup::new(row, 1, cols, panel));
    }
    let r = geom.rows(1);
    let mut cc = CompiledConv {
        name: layer.name.clone(),
        geom: *geom,
        relu: layer.relu,
        bias,
        flops: 2 * kept_weights * r,
        kind: ConvKind::Pattern { groups },
        tile: GemmTile::default(),
        packed: None,
        sched: None,
        kernel: None,
        threads: 0,
        fused: None,
        int8: None,
    };
    cc.finalize();
    cc
}

/// BlockPunched (PCONV/GRIM): the mask is one kept-K-column map per
/// `g_m`-filter block, `(PP, C*Ks)` flat with `PP = ceil(M/g_m)` — the
/// punched holes are uniform across every kernel in the block, so the
/// block compiles to one dense `(m_eff, kept)` panel over a compacted K
/// with a single shared column index map (no row compaction, fully
/// vectorizable: the same gathered-panel kernels KGS streams, at block
/// width instead of per-group width).
fn compile_block_punched(
    layer: &ConvLayer,
    geom: &Conv3dGeometry,
    w: &[f32],
    bias: Vec<f32>,
    mask: &[bool],
    g_m: usize,
) -> CompiledConv {
    let (m, c) = (layer.out_ch, layer.in_ch);
    let ks: usize = layer.kernel.iter().product();
    let k = c * ks;
    let pp = ceil_div(m, g_m);
    assert_eq!(mask.len(), pp * k, "block_punched mask shape");
    let mut groups = Vec::with_capacity(pp);
    let mut kept_weights = 0usize;
    for p in 0..pp {
        let m0 = p * g_m;
        let m_eff = g_m.min(m - m0);
        // Shared kept-column map for the whole block, ascending K order.
        let cols: Vec<u32> = (0..k)
            .filter(|&ki| mask[p * k + ki])
            .map(|ki| ki as u32)
            .collect();
        if cols.is_empty() {
            continue;
        }
        let mut panel = Vec::with_capacity(m_eff * cols.len());
        for im in 0..m_eff {
            let base = (m0 + im) * k;
            for &ki in &cols {
                panel.push(w[base + ki as usize]);
            }
        }
        kept_weights += panel.len();
        groups.push(KgsGroup::new(m0, m_eff, cols, panel));
    }
    let r = geom.rows(1);
    let mut cc = CompiledConv {
        name: layer.name.clone(),
        geom: *geom,
        relu: layer.relu,
        bias,
        flops: 2 * kept_weights * r,
        kind: ConvKind::BlockPunched { groups },
        tile: GemmTile::default(),
        packed: None,
        sched: None,
        kernel: None,
        threads: 0,
        fused: None,
        int8: None,
    };
    cc.finalize();
    cc
}

/// Filter: keep surviving rows of the dense weight matrix.
fn compile_filter(
    layer: &ConvLayer,
    geom: &Conv3dGeometry,
    w: &[f32],
    bias: Vec<f32>,
    mask: &[bool],
) -> CompiledConv {
    let m = layer.out_ch;
    let k = geom.cols();
    assert_eq!(mask.len(), m, "filter mask shape");
    let kept: Vec<u32> = (0..m).filter(|&i| mask[i]).map(|i| i as u32).collect();
    let mut wmat = Vec::with_capacity(kept.len() * k);
    for &i in &kept {
        wmat.extend_from_slice(&w[i as usize * k..(i as usize + 1) * k]);
    }
    let r = geom.rows(1);
    let mut cc = CompiledConv {
        name: layer.name.clone(),
        geom: *geom,
        relu: layer.relu,
        bias,
        flops: 2 * wmat.len() * r,
        kind: ConvKind::Filter { rows: kept, wmat },
        tile: GemmTile::default(),
        packed: None,
        sched: None,
        kernel: None,
        threads: 0,
        fused: None,
        int8: None,
    };
    cc.finalize();
    cc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{TensorRef, WeightRefs};

    pub(crate) fn layer(m: usize, c: usize, k: [usize; 3]) -> ConvLayer {
        let dummy = TensorRef { offset: 0, shape: vec![], dtype: "f32".into() };
        ConvLayer {
            name: "t".into(),
            in_ch: c,
            out_ch: m,
            kernel: k,
            stride: [1, 1, 1],
            padding: [k[0] / 2, k[1] / 2, k[2] / 2],
            relu: false,
            weights: WeightRefs { w: dummy.clone(), b: dummy },
            weights_sparse: None,
            unit_mask: None,
            quant: None,
        }
    }

    pub(crate) fn geom_for(l: &ConvLayer, sp: [usize; 3]) -> Conv3dGeometry {
        Conv3dGeometry {
            in_ch: l.in_ch,
            out_ch: l.out_ch,
            kernel: l.kernel,
            stride: l.stride,
            padding: l.padding,
            in_spatial: sp,
        }
    }

    #[test]
    fn kgs_compaction_counts() {
        let l = layer(8, 8, [3, 3, 3]);
        let g = geom_for(&l, [4, 4, 4]);
        let w = vec![1.0f32; 8 * 8 * 27];
        // Keep 9 of 27 locations in every group.
        let mut mask = vec![false; 2 * 2 * 27];
        for grp in 0..4 {
            for loc in 0..9 {
                mask[grp * 27 + loc] = true;
            }
        }
        let cc = compile_kgs(&l, &g, &w, vec![0.0; 8], &mask, 4, 4);
        match &cc.kind {
            ConvKind::Kgs { groups } => {
                assert_eq!(groups.len(), 4);
                for grp in groups {
                    assert_eq!(grp.cols.len(), 4 * 9);
                    assert_eq!(grp.panel.len(), 4 * 4 * 9);
                }
            }
            _ => panic!("expected kgs"),
        }
        // FLOPs reduced 3x vs dense.
        assert_eq!(cc.flops * 3, g.flops(1));
    }

    #[test]
    fn pattern_compaction_per_filter_gather() {
        let l = layer(4, 2, [3, 3, 3]);
        let g = geom_for(&l, [4, 4, 4]);
        let k = 2 * 27;
        let w: Vec<f32> = (0..4 * k).map(|i| i as f32).collect();
        // Every kernel keeps the same 9-tap "pattern"; filter 2 keeps none.
        let mut mask = vec![false; 4 * k];
        for row in [0usize, 1, 3] {
            for c in 0..2 {
                for loc in 0..9 {
                    mask[row * k + c * 27 + loc * 3] = true;
                }
            }
        }
        let cc = compile_pattern(&l, &g, &w, vec![0.0; 4], &mask);
        match &cc.kind {
            ConvKind::Pattern { groups } => {
                assert_eq!(groups.len(), 3, "empty filter emits no group");
                for grp in groups {
                    assert_eq!(grp.m_eff, 1);
                    assert_eq!(grp.cols.len(), 2 * 9);
                    // Ascending fixed gather schedule.
                    assert!(grp.cols.windows(2).all(|w| w[0] < w[1]));
                }
                assert_eq!(groups[0].m0, 0);
                assert_eq!(groups[2].m0, 3);
                // Panel holds the kept weights in column order.
                assert_eq!(groups[0].panel[0], w[0]);
                assert_eq!(groups[0].panel[1], w[3]);
            }
            _ => panic!("expected pattern"),
        }
        assert_eq!(cc.flops, 2 * 3 * 18 * g.rows(1));
    }

    #[test]
    fn block_punched_shared_column_map() {
        let l = layer(6, 2, [3, 3, 3]);
        let g = geom_for(&l, [4, 4, 4]);
        let k = 2 * 27;
        let w: Vec<f32> = (0..6 * k).map(|i| i as f32).collect();
        // pp = ceil(6/4) = 2 blocks; each keeps every third K column.
        let pp = 2;
        let mask: Vec<bool> = (0..pp * k).map(|i| (i % k) % 3 == 0).collect();
        let cc = compile_block_punched(&l, &g, &w, vec![0.0; 6], &mask, 4);
        match &cc.kind {
            ConvKind::BlockPunched { groups } => {
                assert_eq!(groups.len(), 2);
                assert_eq!((groups[0].m0, groups[0].m_eff), (0, 4));
                assert_eq!((groups[1].m0, groups[1].m_eff), (4, 2), "ragged block");
                let kept = k / 3;
                for grp in &groups[..] {
                    assert_eq!(grp.cols.len(), kept, "shared map per block");
                    assert_eq!(grp.panel.len(), grp.m_eff * kept);
                }
                // Dense panel over the compacted K: row 1 of block 0 holds
                // filter 1's weights at the shared kept columns.
                assert_eq!(groups[0].panel[kept], w[k]);
                assert_eq!(groups[0].panel[kept + 1], w[k + 3]);
            }
            _ => panic!("expected block_punched"),
        }
        assert_eq!(cc.flops, 2 * 6 * (k / 3) * g.rows(1));
    }

    #[test]
    fn scheme_names_round_trip() {
        for (name, sch) in [
            ("filter", Scheme::Filter),
            ("vanilla", Scheme::Vanilla),
            ("kgs", Scheme::Kgs),
            ("pattern", Scheme::Pattern),
            ("block_punched", Scheme::BlockPunched),
        ] {
            assert_eq!(Scheme::parse(name), Some(sch));
        }
        assert_eq!(Scheme::parse("nope"), None);
    }

    #[test]
    fn filter_compaction_rows() {
        let l = layer(6, 4, [1, 1, 1]);
        let g = geom_for(&l, [2, 2, 2]);
        let w: Vec<f32> = (0..24).map(|i| i as f32).collect();
        let mask = vec![true, false, true, false, true, false];
        let cc = compile_filter(&l, &g, &w, vec![0.0; 6], &mask);
        match &cc.kind {
            ConvKind::Filter { rows, wmat } => {
                assert_eq!(rows, &[0, 2, 4]);
                assert_eq!(wmat.len(), 3 * 4);
                assert_eq!(wmat[0..4], [0.0, 1.0, 2.0, 3.0]);
                assert_eq!(wmat[4..8], [8.0, 9.0, 10.0, 11.0]);
            }
            _ => panic!("expected filter"),
        }
    }
}

//! Artifact manifests: the layer IR + tensor pool written by
//! `python/compile/export.py`.
//!
//! The manifest is the single source of truth shared by both execution
//! paths: the PJRT runtime (which HLO file to load per variant/batch) and
//! the native executors (layer IR + weights + sparsity masks).

mod manifest;
mod pool;
mod synthetic;

pub use manifest::{
    ConvLayer, DenseLayer, Layer, Manifest, QuantInfo, SparsityInfo, TensorRef,
    WeightRefs,
};
pub use pool::TensorPool;
pub use synthetic::SyntheticC3d;

use crate::tensor::Conv3dGeometry;
use crate::Result;
use std::path::{Path, PathBuf};

/// A fully-loaded model: manifest + tensor pool + resolved paths.
pub struct Model {
    pub manifest: Manifest,
    pub pool: TensorPool,
    pub dir: PathBuf,
}

impl Model {
    /// Load `<dir>/<name>.manifest.json` and its tensor pool.
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::parse(&std::fs::read_to_string(
            dir.join(format!("{name}.manifest.json")),
        )?)?;
        let pool = TensorPool::load(dir.join(&manifest.bin))?;
        Ok(Self { manifest, pool, dir })
    }

    /// Absolute path of an HLO artifact by variant key (e.g. "dense_xla_b1").
    pub fn hlo_path(&self, key: &str) -> Option<PathBuf> {
        self.manifest.hlo.get(key).map(|f| self.dir.join(f))
    }

    /// All conv layers flattened depth-first (matching python `walk_convs`).
    pub fn conv_layers(&self) -> Vec<&ConvLayer> {
        fn walk<'a>(layers: &'a [Layer], out: &mut Vec<&'a ConvLayer>) {
            for l in layers {
                match l {
                    Layer::Conv3d(c) => out.push(c),
                    Layer::Residual { body, shortcut, .. } => {
                        walk(body, out);
                        walk(shortcut, out);
                    }
                    Layer::Concat { branches, .. } => {
                        for b in branches {
                            walk(b, out);
                        }
                    }
                    _ => {}
                }
            }
        }
        let mut v = Vec::new();
        walk(&self.manifest.layers, &mut v);
        v
    }

    /// Conv geometry at the model's native input resolution, walking the IR
    /// to track spatial extents. Returns (layer, geometry) pairs.
    pub fn conv_geometries(&self) -> Vec<(&ConvLayer, Conv3dGeometry)> {
        let sp = [
            self.manifest.input[1],
            self.manifest.input[2],
            self.manifest.input[3],
        ];
        let mut out = Vec::new();
        walk_geom(&self.manifest.layers, self.manifest.input[0], sp, &mut out);
        out
    }
}

/// Walk the IR propagating (channels, spatial) and collecting conv geometry.
/// Returns (out_channels, out_spatial).
fn walk_geom<'a>(
    layers: &'a [Layer],
    in_ch: usize,
    in_sp: [usize; 3],
    out: &mut Vec<(&'a ConvLayer, Conv3dGeometry)>,
) -> (usize, [usize; 3]) {
    let mut ch = in_ch;
    let mut sp = in_sp;
    for l in layers {
        match l {
            Layer::Conv3d(c) => {
                let g = Conv3dGeometry {
                    in_ch: c.in_ch,
                    out_ch: c.out_ch,
                    kernel: c.kernel,
                    stride: c.stride,
                    padding: c.padding,
                    in_spatial: sp,
                };
                sp = g.out_spatial();
                ch = c.out_ch;
                out.push((c, g));
            }
            Layer::MaxPool3d { kernel, stride } => {
                for a in 0..3 {
                    sp[a] = (sp[a] - kernel[a]) / stride[a] + 1;
                }
            }
            Layer::AvgPoolGlobal => sp = [1, 1, 1],
            Layer::Flatten => {}
            Layer::Dense(_) => {}
            Layer::Residual { body, shortcut, .. } => {
                let (ch2, sp2) = walk_geom(body, ch, sp, out);
                if !shortcut.is_empty() {
                    walk_geom(shortcut, ch, sp, out);
                }
                ch = ch2;
                sp = sp2;
            }
            Layer::Concat { branches, .. } => {
                let mut total = 0;
                let mut sp2 = sp;
                for b in branches {
                    let (cb, sb) = walk_geom(b, ch, sp, out);
                    total += cb;
                    sp2 = sb;
                }
                ch = total;
                sp = sp2;
            }
        }
    }
    (ch, sp)
}

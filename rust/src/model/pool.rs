//! The binary tensor pool backing manifest `TensorRef`s.

use super::TensorRef;
use crate::Result;
use std::path::Path;

/// In-memory copy of `<model>.bin`; tensors are sliced out by byte offset.
pub struct TensorPool {
    bytes: Vec<u8>,
}

impl TensorPool {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Ok(Self { bytes: std::fs::read(path)? })
    }

    pub fn from_bytes(bytes: Vec<u8>) -> Self {
        Self { bytes }
    }

    pub fn f32(&self, r: &TensorRef) -> Vec<f32> {
        assert_eq!(r.dtype, "f32", "tensor ref is {}", r.dtype);
        let n = r.numel();
        let raw = &self.bytes[r.offset..r.offset + 4 * n];
        raw.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn i32(&self, r: &TensorRef) -> Vec<i32> {
        assert_eq!(r.dtype, "i32");
        let n = r.numel();
        let raw = &self.bytes[r.offset..r.offset + 4 * n];
        raw.chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn bool(&self, r: &TensorRef) -> Vec<bool> {
        assert_eq!(r.dtype, "u8");
        let n = r.numel();
        self.bytes[r.offset..r.offset + n].iter().map(|&b| b != 0).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trip() {
        let vals = [1.5f32, -2.25, 0.0, 3.0e8];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let pool = TensorPool::from_bytes(bytes);
        let r = TensorRef { offset: 0, shape: vec![2, 2], dtype: "f32".into() };
        assert_eq!(pool.f32(&r), vals);
    }

    #[test]
    fn bool_mask() {
        let pool = TensorPool::from_bytes(vec![1, 0, 1, 1]);
        let r = TensorRef { offset: 0, shape: vec![4], dtype: "u8".into() };
        assert_eq!(pool.bool(&r), vec![true, false, true, true]);
    }

    #[test]
    fn offset_slicing() {
        let mut bytes = vec![0u8; 8];
        bytes.extend_from_slice(&7.0f32.to_le_bytes());
        let pool = TensorPool::from_bytes(bytes);
        let r = TensorRef { offset: 8, shape: vec![1], dtype: "f32".into() };
        assert_eq!(pool.f32(&r), vec![7.0]);
    }
}

//! Manifest types for `<model>.manifest.json` (schema in python export.py),
//! parsed with the in-tree JSON parser (offline build: no serde).

use crate::bail;
use crate::util::error::Result;
use crate::util::Json;
use std::collections::HashMap;

/// Reference into the model's tensor pool (`<model>.bin`).
#[derive(Debug, Clone)]
pub struct TensorRef {
    /// Byte offset into the .bin file (8-byte aligned).
    pub offset: usize,
    pub shape: Vec<usize>,
    /// "f32" | "i32" | "u8".
    pub dtype: String,
}

impl TensorRef {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            offset: j.req("offset")?.as_usize()?,
            shape: j.req("shape")?.usize_vec()?,
            dtype: j.req("dtype")?.as_str()?.to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct WeightRefs {
    pub w: TensorRef,
    pub b: TensorRef,
}

impl WeightRefs {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            w: TensorRef::from_json(j.req("w")?)?,
            b: TensorRef::from_json(j.req("b")?)?,
        })
    }
}

/// Exported symmetric-quantization parameters for one conv layer (the
/// `python/compile/quantize.py` convention): per-output-channel weight
/// scales (`absmax/127`) and an optional static activation scale.
/// Optional in the manifest — layers without it are quantized at compile
/// time from the f32 weights with the identical rust-side algorithm.
#[derive(Debug, Clone)]
pub struct QuantInfo {
    pub w_scales: Vec<f32>,
    pub in_scale: Option<f32>,
}

impl QuantInfo {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            w_scales: j
                .req("w_scales")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64().map(|x| x as f32))
                .collect::<Result<Vec<f32>>>()?,
            in_scale: match j.get("in_scale") {
                Some(Json::Num(n)) => Some(*n as f32),
                _ => None,
            },
        })
    }
}

#[derive(Debug, Clone)]
pub struct ConvLayer {
    pub name: String,
    pub in_ch: usize,
    pub out_ch: usize,
    pub kernel: [usize; 3],
    pub stride: [usize; 3],
    pub padding: [usize; 3],
    pub relu: bool,
    pub weights: WeightRefs,
    /// Pruned+retrained weights for the sparse deployment (masked).
    pub weights_sparse: Option<WeightRefs>,
    /// Per-unit sparsity mask (shape depends on the scheme; see codegen).
    pub unit_mask: Option<TensorRef>,
    /// Exported quantization scales for the int8 path (optional).
    pub quant: Option<QuantInfo>,
}

#[derive(Debug, Clone)]
pub struct DenseLayer {
    pub name: String,
    pub in_dim: usize,
    pub out_dim: usize,
    pub relu: bool,
    pub weights: WeightRefs,
    /// Retrained weights for the sparse deployment.
    pub weights_sparse: Option<WeightRefs>,
}

/// One node of the nested layer IR.
#[derive(Debug, Clone)]
pub enum Layer {
    Conv3d(ConvLayer),
    MaxPool3d {
        kernel: [usize; 3],
        stride: [usize; 3],
    },
    AvgPoolGlobal,
    Flatten,
    Dense(DenseLayer),
    Residual {
        name: String,
        body: Vec<Layer>,
        shortcut: Vec<Layer>,
    },
    Concat {
        name: String,
        branches: Vec<Vec<Layer>>,
    },
}

impl Layer {
    fn from_json(j: &Json) -> Result<Layer> {
        let kind = j.req("kind")?.as_str()?;
        Ok(match kind {
            "conv3d" => Layer::Conv3d(ConvLayer {
                name: j.req("name")?.as_str()?.to_string(),
                in_ch: j.req("in_ch")?.as_usize()?,
                out_ch: j.req("out_ch")?.as_usize()?,
                kernel: j.req("kernel")?.usize3()?,
                stride: j.req("stride")?.usize3()?,
                padding: j.req("padding")?.usize3()?,
                relu: j.req("relu")?.as_bool()?,
                weights: WeightRefs::from_json(j.req("weights")?)?,
                weights_sparse: match j.get("weights_sparse") {
                    Some(m) if !m.is_null() => Some(WeightRefs::from_json(m)?),
                    _ => None,
                },
                unit_mask: match j.get("unit_mask") {
                    Some(m) if !m.is_null() => Some(TensorRef::from_json(m)?),
                    _ => None,
                },
                quant: match j.get("quant") {
                    Some(m) if !m.is_null() => Some(QuantInfo::from_json(m)?),
                    _ => None,
                },
            }),
            "maxpool3d" => Layer::MaxPool3d {
                kernel: j.req("kernel")?.usize3()?,
                stride: j.req("stride")?.usize3()?,
            },
            "avgpool_global" => Layer::AvgPoolGlobal,
            "flatten" => Layer::Flatten,
            "dense" => Layer::Dense(DenseLayer {
                name: j.req("name")?.as_str()?.to_string(),
                in_dim: j.req("in_dim")?.as_usize()?,
                out_dim: j.req("out_dim")?.as_usize()?,
                relu: j.req("relu")?.as_bool()?,
                weights: WeightRefs::from_json(j.req("weights")?)?,
                weights_sparse: match j.get("weights_sparse") {
                    Some(m) if !m.is_null() => Some(WeightRefs::from_json(m)?),
                    _ => None,
                },
            }),
            "residual" => Layer::Residual {
                name: j.req("name")?.as_str()?.to_string(),
                body: parse_layers(j.req("body")?)?,
                shortcut: parse_layers(j.req("shortcut")?)?,
            },
            "concat" => Layer::Concat {
                name: j.req("name")?.as_str()?.to_string(),
                branches: j
                    .req("branches")?
                    .as_arr()?
                    .iter()
                    .map(parse_layers)
                    .collect::<Result<Vec<_>>>()?,
            },
            other => bail!("unknown layer kind {other:?}"),
        })
    }
}

fn parse_layers(j: &Json) -> Result<Vec<Layer>> {
    j.as_arr()?.iter().map(Layer::from_json).collect()
}

#[derive(Debug, Clone)]
pub struct SparsityInfo {
    pub scheme: String,
    pub g_m: usize,
    pub g_n: usize,
    pub rate: f64,
    pub eval_acc: Option<f64>,
    pub flops_sparse: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    /// (C, D, H, W) of a single clip.
    pub input: [usize; 4],
    pub num_classes: usize,
    pub flops_dense: usize,
    pub layers: Vec<Layer>,
    /// variant key ("dense_xla_b1", "kgs_pallas_b1", ...) -> file name.
    pub hlo: HashMap<String, String>,
    pub bin: String,
    pub eval_acc: Option<f64>,
    pub sparsity: Option<SparsityInfo>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let input = j.req("input")?.usize_vec()?;
        if input.len() != 4 {
            bail!("input must be (C, D, H, W)");
        }
        let mut hlo = HashMap::new();
        for (k, v) in j.req("hlo")?.as_obj()? {
            hlo.insert(k.clone(), v.as_str()?.to_string());
        }
        let sparsity = match j.get("sparsity") {
            Some(s) if !s.is_null() => Some(SparsityInfo {
                scheme: s.req("scheme")?.as_str()?.to_string(),
                g_m: s.req("g_m")?.as_usize()?,
                g_n: s.req("g_n")?.as_usize()?,
                rate: s.req("rate")?.as_f64()?,
                eval_acc: match s.get("eval_acc") {
                    Some(Json::Num(n)) => Some(*n),
                    _ => None,
                },
                flops_sparse: s.req("flops_sparse")?.as_usize()?,
            }),
            _ => None,
        };
        Ok(Manifest {
            model: j.req("model")?.as_str()?.to_string(),
            input: [input[0], input[1], input[2], input[3]],
            num_classes: j.req("num_classes")?.as_usize()?,
            flops_dense: j.req("flops_dense")?.as_usize()?,
            layers: parse_layers(j.req("layers")?)?,
            hlo,
            bin: j.req("bin")?.as_str()?.to_string(),
            eval_acc: match j.get("eval_acc") {
                Some(Json::Num(n)) => Some(*n),
                _ => None,
            },
            sparsity,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "model": "tiny", "input": [3, 4, 8, 8], "num_classes": 2,
      "flops_dense": 1000,
      "layers": [
        {"kind": "conv3d", "name": "c1", "in_ch": 3, "out_ch": 4,
         "kernel": [3,3,3], "stride": [1,1,1], "padding": [1,1,1],
         "relu": true,
         "weights": {"w": {"offset": 0, "shape": [4,3,3,3,3], "dtype": "f32"},
                     "b": {"offset": 1296, "shape": [4], "dtype": "f32"}},
         "unit_mask": {"offset": 1312, "shape": [1,1,27], "dtype": "u8"},
         "quant": {"w_scales": [0.0125, 0.5, 1.0, 0.25], "in_scale": 0.75}},
        {"kind": "maxpool3d", "kernel": [2,2,2], "stride": [2,2,2]},
        {"kind": "residual", "name": "r1", "body": [], "shortcut": []},
        {"kind": "flatten"},
        {"kind": "dense", "name": "fc", "in_dim": 64, "out_dim": 2,
         "relu": false,
         "weights": {"w": {"offset": 2000, "shape": [64,2], "dtype": "f32"},
                     "b": {"offset": 2512, "shape": [2], "dtype": "f32"}}}
      ],
      "hlo": {"dense_xla_b1": "tiny.hlo.txt"},
      "bin": "tiny.bin", "eval_acc": 0.9, "sparsity": null
    }"#;

    #[test]
    fn parses_full_manifest() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.model, "tiny");
        assert_eq!(m.input, [3, 4, 8, 8]);
        assert_eq!(m.layers.len(), 5);
        match &m.layers[0] {
            Layer::Conv3d(c) => {
                assert_eq!(c.name, "c1");
                assert!(c.unit_mask.is_some());
                assert_eq!(c.weights.b.shape, vec![4]);
                let q = c.quant.as_ref().expect("quant parsed");
                assert_eq!(q.w_scales, vec![0.0125, 0.5, 1.0, 0.25]);
                assert_eq!(q.in_scale, Some(0.75));
            }
            _ => panic!("expected conv"),
        }
        assert_eq!(m.eval_acc, Some(0.9));
        assert!(m.sparsity.is_none());
        assert_eq!(m.hlo["dense_xla_b1"], "tiny.hlo.txt");
    }

    #[test]
    fn rejects_bad_kind() {
        let bad = DOC.replace("maxpool3d", "nopool");
        assert!(Manifest::parse(&bad).is_err());
    }
}

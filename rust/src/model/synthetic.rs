//! In-memory synthetic models: a C3D-shaped conv stack with deterministic
//! weights, KGS masks and an in-memory tensor pool, so benches, tests and
//! the serving demo run on a clean machine without `make artifacts`.
//! Shapes follow C3D's conv/pool rhythm (AAAI'21 Table 2 workload) at a
//! configurable width/resolution.

use super::{
    ConvLayer, DenseLayer, Layer, Manifest, Model, SparsityInfo, TensorPool,
    TensorRef, WeightRefs,
};
use crate::tensor::Tensor5;
use std::collections::HashMap;

/// Configuration for [`Model::synthetic_c3d`].
#[derive(Debug, Clone)]
pub struct SyntheticC3d {
    /// Channel widths of the four conv stages (C3D: 64/128/256/512-ish;
    /// the default is scaled down to keep benches minutes-free).
    pub widths: [usize; 4],
    /// Input clip frames (D).
    pub frames: usize,
    /// Input clip height/width.
    pub size: usize,
    pub classes: usize,
    /// KGS kept kernel locations of 27 per (4x4) group — 9 ≈ the paper's
    /// 3x pruning rate on 3x3x3 kernels.
    pub keep_locs: usize,
}

impl Default for SyntheticC3d {
    fn default() -> Self {
        Self { widths: [16, 32, 64, 64], frames: 16, size: 32, classes: 8, keep_locs: 9 }
    }
}

impl SyntheticC3d {
    /// Small enough for unit tests (fractions of a second per forward).
    pub fn tiny() -> Self {
        Self { widths: [4, 8, 8, 8], frames: 4, size: 8, classes: 8, keep_locs: 9 }
    }
}

/// Accumulates the in-memory `<model>.bin` byte pool.
struct PoolBuilder {
    bytes: Vec<u8>,
}

impl PoolBuilder {
    fn f32s(&mut self, shape: Vec<usize>, data: &[f32]) -> TensorRef {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let offset = self.bytes.len();
        for v in data {
            self.bytes.extend_from_slice(&v.to_le_bytes());
        }
        TensorRef { offset, shape, dtype: "f32".into() }
    }

    fn mask(&mut self, shape: Vec<usize>, bits: &[bool]) -> TensorRef {
        assert_eq!(shape.iter().product::<usize>(), bits.len());
        let offset = self.bytes.len();
        self.bytes.extend(bits.iter().map(|&b| b as u8));
        TensorRef { offset, shape, dtype: "u8".into() }
    }
}

fn conv(
    pb: &mut PoolBuilder,
    name: &str,
    cin: usize,
    cout: usize,
    keep_locs: usize,
    scheme: &str,
    seed: u64,
) -> Layer {
    let w = Tensor5::random([cout, cin, 3, 3, 3], seed).data;
    let b = Tensor5::random([1, 1, 1, 1, cout], seed ^ 0xB1A5).data;
    let weights = WeightRefs {
        w: pb.f32s(vec![cout, cin, 3, 3, 3], &w),
        b: pb.f32s(vec![cout], &b),
    };
    // Every scheme keeps `keep_locs` of 27 taps per kernel, spread
    // deterministically (gcd(7, 27) = 1 → distinct), so the three
    // synthetic variants land on the exact same FLOP pruning rate — the
    // matched-rate frontier the table-3 bench measures.
    let (g_m, g_n, ks) = (4usize, 4usize, 27usize);
    let keep = keep_locs.min(ks);
    let unit_mask = Some(match scheme {
        // Pattern (PatDNN): per-element mask; each kernel (m, c) picks one
        // of an 8-entry tap-pattern dictionary.
        "pattern" => {
            let mut mask = vec![false; cout * cin * ks];
            for m in 0..cout {
                for c in 0..cin {
                    let pat = (m * 5 + c * 3) % 8;
                    for i in 0..keep {
                        mask[(m * cin + c) * ks + (i * 7 + pat) % ks] = true;
                    }
                }
            }
            pb.mask(vec![cout, cin, 3, 3, 3], &mask)
        }
        // BlockPunched (PCONV/GRIM): one kept-K-column map per 4-filter
        // block, holes uniform across the block's kernels.
        "block_punched" => {
            let pp = cout.div_ceil(g_m);
            let k = cin * ks;
            let mut mask = vec![false; pp * k];
            for p in 0..pp {
                for (ki, v) in mask[p * k..(p + 1) * k].iter_mut().enumerate() {
                    // loc → (loc*7 + p) % 27 is a bijection per channel, so
                    // exactly `keep` of every kernel's 27 taps survive.
                    *v = ((ki % ks) * 7 + p) % ks < keep;
                }
            }
            pb.mask(vec![pp, cin, 3, 3, 3], &mask)
        }
        // KGS (default): mask over (4x4) kernel groups.
        _ => {
            let (pp, qq) = (cout.div_ceil(g_m), cin.div_ceil(g_n));
            let mut mask = vec![false; pp * qq * ks];
            for g in 0..pp * qq {
                for i in 0..keep {
                    mask[g * ks + (i * 7 + g) % ks] = true;
                }
            }
            pb.mask(vec![pp, qq, ks], &mask)
        }
    });
    Layer::Conv3d(ConvLayer {
        name: name.into(),
        in_ch: cin,
        out_ch: cout,
        kernel: [3, 3, 3],
        stride: [1, 1, 1],
        padding: [1, 1, 1],
        relu: true,
        weights,
        weights_sparse: None,
        unit_mask,
        quant: None,
    })
}

fn dense(
    pb: &mut PoolBuilder,
    name: &str,
    din: usize,
    dout: usize,
    relu: bool,
    seed: u64,
) -> Layer {
    let w = Tensor5::random([1, 1, 1, din, dout], seed).data;
    let b = Tensor5::random([1, 1, 1, 1, dout], seed ^ 0xB1A5).data;
    Layer::Dense(DenseLayer {
        name: name.into(),
        in_dim: din,
        out_dim: dout,
        relu,
        weights: WeightRefs {
            w: pb.f32s(vec![din, dout], &w),
            b: pb.f32s(vec![dout], &b),
        },
        weights_sparse: None,
    })
}

impl Model {
    /// Build a C3D-shaped model entirely in memory (no artifact files).
    /// Deterministic for a given config, so engines built from the same
    /// config produce bit-identical logits.
    pub fn synthetic_c3d(cfg: SyntheticC3d) -> Model {
        Model::synthetic_c3d_scheme(cfg, "kgs")
    }

    /// [`Model::synthetic_c3d`] with a chosen sparsity scheme — `"kgs"`,
    /// `"pattern"` (PatDNN dictionary masks) or `"block_punched"`
    /// (PCONV/GRIM shared punched-column maps). All three keep the same
    /// per-kernel tap count, so benches and tests compare schemes at a
    /// matched FLOP pruning rate, artifact-free.
    pub fn synthetic_c3d_scheme(cfg: SyntheticC3d, scheme: &str) -> Model {
        assert!(
            matches!(scheme, "kgs" | "pattern" | "block_punched"),
            "unsupported synthetic scheme {scheme:?}"
        );
        let [w1, w2, w3, w4] = cfg.widths;
        let mut pb = PoolBuilder { bytes: Vec::new() };
        let layers = vec![
            conv(&mut pb, "conv1", 3, w1, cfg.keep_locs, scheme, 11),
            Layer::MaxPool3d { kernel: [1, 2, 2], stride: [1, 2, 2] },
            conv(&mut pb, "conv2", w1, w2, cfg.keep_locs, scheme, 12),
            Layer::MaxPool3d { kernel: [2, 2, 2], stride: [2, 2, 2] },
            conv(&mut pb, "conv3a", w2, w3, cfg.keep_locs, scheme, 13),
            conv(&mut pb, "conv3b", w3, w3, cfg.keep_locs, scheme, 14),
            Layer::MaxPool3d { kernel: [2, 2, 2], stride: [2, 2, 2] },
            conv(&mut pb, "conv4", w3, w4, cfg.keep_locs, scheme, 15),
            Layer::AvgPoolGlobal,
            dense(&mut pb, "fc1", w4, 2 * w4, true, 16),
            dense(&mut pb, "fc2", 2 * w4, cfg.classes, false, 17),
        ];
        let manifest = Manifest {
            model: "c3d-synthetic".into(),
            input: [3, cfg.frames, cfg.size, cfg.size],
            num_classes: cfg.classes,
            flops_dense: 0, // patched below once geometries are walkable
            layers,
            hlo: HashMap::new(),
            bin: "<in-memory>".into(),
            eval_acc: None,
            sparsity: Some(SparsityInfo {
                scheme: scheme.into(),
                g_m: 4,
                g_n: 4,
                rate: 27.0 / cfg.keep_locs.max(1) as f64,
                eval_acc: None,
                flops_sparse: 0,
            }),
        };
        let mut model = Model {
            manifest,
            pool: TensorPool::from_bytes(pb.bytes),
            dir: std::path::PathBuf::from("."),
        };
        let flops: usize =
            model.conv_geometries().iter().map(|(_, g)| g.flops(1)).sum();
        model.manifest.flops_dense = flops;
        if let Some(s) = model.manifest.sparsity.as_mut() {
            s.flops_sparse = flops * cfg.keep_locs.min(27) / 27;
        }
        model
    }

    /// An R(2+1)D-flavored synthetic graph exercising the branching layer
    /// kinds: a conv stem, a `Residual` block (identity shortcut) and a
    /// two-branch `Concat`, then global pooling and a dense head. This is
    /// the coverage model for activation-buffer recycling through branch
    /// fan-out — the plain C3D stack never forks its value flow.
    pub fn synthetic_residual(cfg: SyntheticC3d) -> Model {
        let [w1, w2, ..] = cfg.widths;
        let mut pb = PoolBuilder { bytes: Vec::new() };
        let layers = vec![
            conv(&mut pb, "stem", 3, w1, cfg.keep_locs, "kgs", 21),
            Layer::Residual {
                name: "res1".into(),
                body: vec![conv(&mut pb, "res1_conv", w1, w1, cfg.keep_locs, "kgs", 22)],
                shortcut: vec![],
            },
            Layer::MaxPool3d { kernel: [1, 2, 2], stride: [1, 2, 2] },
            Layer::Concat {
                name: "mix".into(),
                branches: vec![
                    vec![conv(&mut pb, "mix_a", w1, w2, cfg.keep_locs, "kgs", 23)],
                    vec![conv(&mut pb, "mix_b", w1, w2, cfg.keep_locs, "kgs", 24)],
                ],
            },
            Layer::AvgPoolGlobal,
            dense(&mut pb, "head", 2 * w2, cfg.classes, false, 25),
        ];
        let manifest = Manifest {
            model: "r2plus1d-synthetic".into(),
            input: [3, cfg.frames, cfg.size, cfg.size],
            num_classes: cfg.classes,
            flops_dense: 0,
            layers,
            hlo: HashMap::new(),
            bin: "<in-memory>".into(),
            eval_acc: None,
            sparsity: Some(SparsityInfo {
                scheme: "kgs".into(),
                g_m: 4,
                g_n: 4,
                rate: 27.0 / cfg.keep_locs.max(1) as f64,
                eval_acc: None,
                flops_sparse: 0,
            }),
        };
        let mut model = Model {
            manifest,
            pool: TensorPool::from_bytes(pb.bytes),
            dir: std::path::PathBuf::from("."),
        };
        let flops: usize =
            model.conv_geometries().iter().map(|(_, g)| g.flops(1)).sum();
        model.manifest.flops_dense = flops;
        if let Some(s) = model.manifest.sparsity.as_mut() {
            s.flops_sparse = flops * cfg.keep_locs.min(27) / 27;
        }
        model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_model_compiles_and_walks() {
        let m = Model::synthetic_c3d(SyntheticC3d::tiny());
        assert_eq!(m.manifest.input, [3, 4, 8, 8]);
        let geoms = m.conv_geometries();
        assert_eq!(geoms.len(), 5); // conv1, conv2, conv3a, conv3b, conv4
        // Spatial rhythm: 4x8x8 -> 4x4x4 -> 2x2x2 (conv3a/b) -> 1x1x1.
        assert_eq!(geoms[0].1.in_spatial, [4, 8, 8]);
        assert_eq!(geoms[1].1.in_spatial, [4, 4, 4]);
        assert_eq!(geoms[2].1.in_spatial, [2, 2, 2]);
        assert_eq!(geoms[3].1.in_spatial, [2, 2, 2]);
        assert_eq!(geoms[4].1.in_spatial, [1, 1, 1]);
        assert!(m.manifest.flops_dense > 0);
        // Weight refs resolve against the in-memory pool.
        for c in m.conv_layers() {
            assert_eq!(m.pool.f32(&c.weights.w).len(), c.out_ch * c.in_ch * 27);
            assert!(c.unit_mask.is_some());
        }
    }

    #[test]
    fn scheme_variants_shapes_and_rates() {
        let kgs = Model::synthetic_c3d_scheme(SyntheticC3d::tiny(), "kgs");
        let pat = Model::synthetic_c3d_scheme(SyntheticC3d::tiny(), "pattern");
        let bp = Model::synthetic_c3d_scheme(SyntheticC3d::tiny(), "block_punched");
        assert_eq!(pat.manifest.sparsity.as_ref().unwrap().scheme, "pattern");
        assert_eq!(
            bp.manifest.sparsity.as_ref().unwrap().scheme,
            "block_punched"
        );
        // Matched FLOP rate across schemes by construction.
        assert_eq!(
            kgs.manifest.sparsity.as_ref().unwrap().flops_sparse,
            pat.manifest.sparsity.as_ref().unwrap().flops_sparse,
        );
        for c in pat.conv_layers() {
            let mask = pat.pool.bool(c.unit_mask.as_ref().unwrap());
            assert_eq!(mask.len(), c.out_ch * c.in_ch * 27, "per-element mask");
            // Every kernel keeps exactly keep_locs taps.
            for kern in mask.chunks(27) {
                assert_eq!(kern.iter().filter(|&&b| b).count(), 9);
            }
        }
        for c in bp.conv_layers() {
            let mask = bp.pool.bool(c.unit_mask.as_ref().unwrap());
            let k = c.in_ch * 27;
            assert_eq!(mask.len(), c.out_ch.div_ceil(4) * k, "per-block map");
            for block in mask.chunks(k) {
                assert_eq!(block.iter().filter(|&&b| b).count(), c.in_ch * 9);
            }
        }
    }

    #[test]
    fn deterministic_across_builds() {
        let a = Model::synthetic_c3d(SyntheticC3d::tiny());
        let b = Model::synthetic_c3d(SyntheticC3d::tiny());
        let ca = a.conv_layers();
        let cb = b.conv_layers();
        assert_eq!(a.pool.f32(&ca[0].weights.w), b.pool.f32(&cb[0].weights.w));
    }
}

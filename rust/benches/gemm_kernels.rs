//! Micro-benchmarks of the GEMM kernels (perf-pass instrumentation):
//! untuned vs blocked vs blocked-with-bigger-tiles on Table-2-sized GEMMs.

use rt3d::codegen::GemmTile;
use rt3d::executors::gemm;
use rt3d::tensor::Mat;
use rt3d::util::bench::BenchGroup;
use std::time::Duration;

fn main() {
    println!(
        "gemm_kernels: blocked kernels run on {} executor threads (RT3D_THREADS)",
        rt3d::util::pool::ThreadPool::global().threads()
    );
    // (M, K, R) shapes drawn from c3d layers at width 8 / 16x32x32 input.
    let shapes = [
        (16usize, 216usize, 8192usize),
        (64, 864, 2048),
        (64, 1728, 512),
    ];
    let mut group = BenchGroup::new("gemm_kernels").budget(Duration::from_secs(2));
    for (m, k, r) in shapes {
        let w = Mat::random(m, k, 1);
        let p = Mat::random(k, r, 2);
        let gflops = (2 * m * k * r) as f64 / 1e9;
        let mut out = Mat::zeros(m, r);
        let ru = group
            .bench(&format!("untuned/{m}x{k}x{r}"), || {
                out.data.fill(0.0);
                gemm::matmul_untuned(&w.data, m, &p, &mut out);
            })
            .median_s;
        let mut results = vec![("untuned", ru)];
        for tile in [
            GemmTile::default(),
            GemmTile { mr: 8, rc: 1024, kc: 256 },
            GemmTile { mr: 8, rc: 256, kc: 512 },
        ] {
            let label =
                format!("blocked_mr{}rc{}kc{}/{m}x{k}x{r}", tile.mr, tile.rc, tile.kc);
            let rb = group
                .bench(&label, || {
                    out.data.fill(0.0);
                    gemm::gemm_dense(&w.data, m, &p, &mut out, tile);
                })
                .median_s;
            results.push(("blocked", rb));
        }
        for (label, t) in &results {
            println!(
                "gemm {m}x{k}x{r} {label}: {:.2} GFLOP/s",
                gflops / t
            );
        }
    }
}

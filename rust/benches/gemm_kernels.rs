//! Micro-benchmarks of the GEMM kernels (perf-pass instrumentation):
//! untuned vs the PR-1 strided scalar kernel vs the prepacked scalar and
//! prepacked SIMD kernels, on Table-2-sized GEMMs. The headline number is
//! `speedup_packed_simd_vs_pr1` — the acceptance gate for the prepacking +
//! SIMD work is >= 1.5x on at least one shape.
//!
//! A second sweep times the full conv-shaped path both ways —
//! **materialized** (im2col into the `(K, R)` patch matrix + GEMM) vs
//! **fused implicit GEMM** (per-worker packed patch panels) — asserting
//! bit-identity and recording each path's measured peak scratch bytes
//! (`fused_peak_scratch_mb` / `materialized_peak_scratch_mb`, gated by
//! `scripts/check_bench_regression.py`). Emits `BENCH_gemm_kernels.json`
//! at the repo root with detected ISA, selected kernel and per-shape
//! GFLOP/s.

use rt3d::codegen::{
    self, absmax, quant_scale, quantize_span, GemmTile, KernelArch, PackedDense,
    PackedDenseI8,
};
use rt3d::executors::gemm::{self, GemmCtx};
use rt3d::executors::{self, AccSlabs, ScratchArena};
use rt3d::model::{ConvLayer, TensorRef, WeightRefs};
use rt3d::tensor::{Conv3dGeometry, Mat, MatI8, Tensor5};
use rt3d::util::bench::{budget_from_env, write_repo_json, BenchGroup};
use rt3d::util::pool::ThreadPool;

fn main() {
    let pool = ThreadPool::global();
    let slabs = AccSlabs::global();
    let active = KernelArch::active();
    println!(
        "gemm_kernels: threads={} isa_detected={} kernel={} lanes={}",
        pool.threads(),
        KernelArch::best_supported().name(),
        active.name(),
        active.lanes()
    );
    // (M, K, R) shapes drawn from c3d layers at width 8 / 16x32x32 input.
    let shapes = [
        (16usize, 216usize, 8192usize),
        (64, 864, 2048),
        (64, 1728, 512),
    ];
    let tile = GemmTile::default();
    let mut group = BenchGroup::new("gemm_kernels").budget(budget_from_env(2000));
    let mut entries = Vec::new();
    let mut int8_entries = Vec::new();
    let (mut int8_best, mut int8_speedup_best) = (0.0f64, 0.0f64);
    for (m, k, r) in shapes {
        let w = Mat::random(m, k, 1);
        let p = Mat::random(k, r, 2);
        let gflop = (2 * m * k * r) as f64 / 1e9;
        let packed = PackedDense::pack(&w.data, m, k, tile.mr);
        let mut out = Mat::zeros(m, r);

        let t_untuned = group
            .bench(&format!("untuned/{m}x{k}x{r}"), || {
                out.data.fill(0.0);
                gemm::matmul_untuned(&w.data, m, &p, &mut out);
            })
            .median_s;
        // PR-1 baseline: blocked, scalar, strided weight loads.
        let t_pr1 = group
            .bench(&format!("pr1_strided/{m}x{k}x{r}"), || {
                out.data.fill(0.0);
                gemm::gemm_dense_unpacked(&w.data, m, &p, &mut out, tile, pool, slabs);
            })
            .median_s;
        let scalar_ctx =
            GemmCtx { tile, kernel: KernelArch::Scalar, cap: usize::MAX, pool, slabs };
        let t_packed_scalar = group
            .bench(&format!("packed_scalar/{m}x{k}x{r}"), || {
                gemm::gemm_dense_packed(&packed, &p, &mut out, &scalar_ctx);
            })
            .median_s;
        let simd_ctx = GemmCtx { kernel: active, ..scalar_ctx };
        let t_packed_simd = group
            .bench(&format!("packed_{}/{m}x{k}x{r}", active.name()), || {
                gemm::gemm_dense_packed(&packed, &p, &mut out, &simd_ctx);
            })
            .median_s;

        // Sanity: the SIMD path must be bit-identical to scalar.
        let mut a = Mat::zeros(m, r);
        gemm::gemm_dense_packed(&packed, &p, &mut a, &scalar_ctx);
        let mut b = Mat::zeros(m, r);
        gemm::gemm_dense_packed(&packed, &p, &mut b, &simd_ctx);
        assert_eq!(a.data, b.data, "SIMD output must be bit-identical to scalar");

        // ---- int8 widening kernels on the same shape ------------------
        // Pre-quantized operands (per-row weight scales, one patch-matrix
        // scale) so the timed region is exactly the widening GEMM +
        // requant epilogue — the work `RT3D_PRECISION=int8` moves onto
        // every layer's inner loop.
        let scales: Vec<f32> =
            (0..m).map(|i| quant_scale(absmax(w.row(i)))).collect();
        let mut qw = vec![0i8; m * k];
        for i in 0..m {
            quantize_span(w.row(i), 1.0 / scales[i], &mut qw[i * k..(i + 1) * k]);
        }
        let qpacked = PackedDenseI8::pack(&qw, m, k, tile.mr);
        let in_scale = quant_scale(absmax(&p.data));
        let mut qp = MatI8::zeros(k, r);
        quantize_span(&p.data, 1.0 / in_scale, &mut qp.data);
        let t_i8_scalar = group
            .bench(&format!("int8_scalar/{m}x{k}x{r}"), || {
                gemm::gemm_dense_packed_i8(
                    &qpacked, &scales, in_scale, &qp, &mut out, &scalar_ctx,
                );
            })
            .median_s;
        let t_i8_simd = group
            .bench(&format!("int8_{}/{m}x{k}x{r}", active.name()), || {
                gemm::gemm_dense_packed_i8(
                    &qpacked, &scales, in_scale, &qp, &mut out, &simd_ctx,
                );
            })
            .median_s;
        let mut ia = Mat::zeros(m, r);
        gemm::gemm_dense_packed_i8(
            &qpacked, &scales, in_scale, &qp, &mut ia, &scalar_ctx,
        );
        let mut ib = Mat::zeros(m, r);
        gemm::gemm_dense_packed_i8(
            &qpacked, &scales, in_scale, &qp, &mut ib, &simd_ctx,
        );
        assert_eq!(
            ia.data, ib.data,
            "int8 SIMD output must be bit-identical to int8 scalar"
        );
        let t_i8 = t_i8_simd.min(t_i8_scalar);
        let i8_speedup = t_packed_simd / t_i8;
        int8_best = int8_best.max(gflop / t_i8);
        int8_speedup_best = int8_speedup_best.max(i8_speedup);
        println!(
            "gemm {m}x{k}x{r} int8: scalar {:.2} GFLOP/s, {} {:.2} GFLOP/s, \
             speedup vs f32 simd {i8_speedup:.2}x",
            gflop / t_i8_scalar,
            active.name(),
            gflop / t_i8_simd
        );
        int8_entries.push(format!(
            "    {{\"m\": {m}, \"k\": {k}, \"r\": {r}, \
             \"int8_scalar_gflops\": {:.4}, \"int8_simd_gflops\": {:.4}, \
             \"speedup_vs_f32_simd\": {:.4}}}",
            gflop / t_i8_scalar,
            gflop / t_i8_simd,
            i8_speedup
        ));

        let speedup = t_pr1 / t_packed_simd;
        for (label, t) in [
            ("untuned", t_untuned),
            ("pr1_strided", t_pr1),
            ("packed_scalar", t_packed_scalar),
            ("packed_simd", t_packed_simd),
        ] {
            println!("gemm {m}x{k}x{r} {label}: {:.2} GFLOP/s", gflop / t);
        }
        println!("gemm {m}x{k}x{r} speedup packed_simd vs pr1: {speedup:.2}x");
        entries.push(format!(
            "    {{\"m\": {m}, \"k\": {k}, \"r\": {r}, \
             \"untuned_gflops\": {:.4}, \"pr1_gflops\": {:.4}, \
             \"packed_scalar_gflops\": {:.4}, \"packed_simd_gflops\": {:.4}, \
             \"speedup_packed_simd_vs_pr1\": {:.4}}}",
            gflop / t_untuned,
            gflop / t_pr1,
            gflop / t_packed_scalar,
            gflop / t_packed_simd,
            speedup
        ));
    }

    // ---- fused implicit-GEMM vs materialized im2col+GEMM ----------------
    // Conv-shaped sweep (M = out_ch, C = in_ch, 3^3 kernels, pad 1):
    // C3D-layer-class shapes where the materialized patch matrix is many
    // MB. Each path runs against its own scratch arena so the measured
    // peak bytes are exactly what an engine would hold for that layer.
    let conv_shapes = [
        (16usize, 3usize, [16usize, 32, 32]), // conv1 class: K=81, R=16384
        (32, 16, [16, 16, 16]),               // conv2 class: K=432, R=4096
        (64, 32, [8, 8, 8]),                  // conv3 class: K=864, R=512
    ];
    let mut fused_entries = Vec::new();
    let (mut fused_best, mut mat_best) = (0.0f64, 0.0f64);
    let (mut fused_peak, mut mat_peak) = (0usize, 0usize);
    for (m, c, sp) in conv_shapes {
        let dummy = TensorRef { offset: 0, shape: vec![], dtype: "f32".into() };
        let layer = ConvLayer {
            name: format!("bench_m{m}c{c}"),
            in_ch: c,
            out_ch: m,
            kernel: [3, 3, 3],
            stride: [1, 1, 1],
            padding: [1, 1, 1],
            relu: true,
            weights: WeightRefs { w: dummy.clone(), b: dummy },
            weights_sparse: None,
            unit_mask: None,
            quant: None,
        };
        let g = Conv3dGeometry {
            in_ch: c,
            out_ch: m,
            kernel: [3, 3, 3],
            stride: [1, 1, 1],
            padding: [1, 1, 1],
            in_spatial: sp,
        };
        let w = Tensor5::random([m, c, 3, 3, 3], 11).data;
        let cc = codegen::compile_conv_dense(&layer, &g, &w, vec![0.0; m]);
        let x = Tensor5::random([1, c, sp[0], sp[1], sp[2]], 12);
        let call = cc.bind(g.in_spatial);
        let gflop = g.flops(1) as f64 / 1e9;
        let (k, r) = (g.cols(), g.rows(1));

        let mut mat_arena = ScratchArena::new(pool.threads());
        let t_mat = group
            .bench(&format!("materialized/m{m}k{k}r{r}"), || {
                let ScratchArena { patches, out, slabs, .. } = &mut mat_arena;
                patches.reset(g.cols(), g.rows(1));
                executors::im2col_t_into_with(&x, &g, patches, pool);
                out.reset(m, patches.cols);
                executors::run_conv_bound(&call, patches, out, pool, slabs);
            })
            .median_s;
        let mut fus_arena = ScratchArena::new(pool.threads());
        let t_fus = group
            .bench(&format!("fused/m{m}k{k}r{r}"), || {
                let ScratchArena { out, slabs, .. } = &mut fus_arena;
                out.reset(m, g.rows(1));
                executors::run_conv_fused(&call, &x, out, pool, slabs);
            })
            .median_s;
        assert_eq!(
            mat_arena.out.data, fus_arena.out.data,
            "fused output must be bit-identical to materialized"
        );
        let (mb, fb) = (mat_arena.peak_bytes(), fus_arena.peak_bytes());
        mat_peak = mat_peak.max(mb);
        fused_peak = fused_peak.max(fb);
        mat_best = mat_best.max(gflop / t_mat);
        fused_best = fused_best.max(gflop / t_fus);
        println!(
            "conv m{m} K{k} R{r}: materialized {:.2} GFLOP/s ({} scratch B), \
             fused {:.2} GFLOP/s ({} scratch B), speedup {:.2}x, scratch {:.1}x smaller",
            gflop / t_mat,
            mb,
            gflop / t_fus,
            fb,
            t_mat / t_fus,
            mb as f64 / fb as f64
        );
        fused_entries.push(format!(
            "    {{\"m\": {m}, \"k\": {k}, \"r\": {r}, \
             \"materialized_gflops\": {:.4}, \"fused_gflops\": {:.4}, \
             \"fused_speedup\": {:.4}, \"materialized_scratch_bytes\": {mb}, \
             \"fused_scratch_bytes\": {fb}}}",
            gflop / t_mat,
            gflop / t_fus,
            t_mat / t_fus
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"gemm_kernels\",\n  \"threads\": {},\n  \
         \"isa_detected\": \"{}\",\n  \"kernel\": \"{}\",\n  \
         \"simd_lanes\": {},\n  \"tile\": {{\"mr\": {}, \"rc\": {}, \"kc\": {}}},\n  \
         \"fused_best_gflops\": {:.4},\n  \"materialized_best_gflops\": {:.4},\n  \
         \"int8_best_gflops\": {:.4},\n  \"int8_speedup_vs_f32\": {:.4},\n  \
         \"fused_peak_scratch_mb\": {:.3},\n  \"materialized_peak_scratch_mb\": {:.3},\n  \
         \"shapes\": [\n{}\n  ],\n  \"int8\": [\n{}\n  ],\n  \"fused\": [\n{}\n  ]\n}}\n",
        pool.threads(),
        KernelArch::best_supported().name(),
        active.name(),
        active.lanes(),
        tile.mr,
        tile.rc,
        tile.kc,
        fused_best,
        mat_best,
        int8_best,
        int8_speedup_best,
        fused_peak as f64 / (1024.0 * 1024.0),
        mat_peak as f64 / (1024.0 * 1024.0),
        entries.join(",\n"),
        int8_entries.join(",\n"),
        fused_entries.join(",\n")
    );
    let out = write_repo_json("BENCH_gemm_kernels.json", &json);
    println!("gemm_kernels: wrote {}", out.display());
}

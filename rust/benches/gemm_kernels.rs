//! Micro-benchmarks of the GEMM kernels (perf-pass instrumentation):
//! untuned vs the PR-1 strided scalar kernel vs the prepacked scalar and
//! prepacked SIMD kernels, on Table-2-sized GEMMs. The headline number is
//! `speedup_packed_simd_vs_pr1` — the acceptance gate for the prepacking +
//! SIMD work is >= 1.5x on at least one shape. Emits
//! `BENCH_gemm_kernels.json` at the repo root with detected ISA, selected
//! kernel and per-shape GFLOP/s.

use rt3d::codegen::{GemmTile, KernelArch, PackedDense};
use rt3d::executors::gemm::{self, GemmCtx};
use rt3d::executors::AccSlabs;
use rt3d::tensor::Mat;
use rt3d::util::bench::{budget_from_env, write_repo_json, BenchGroup};
use rt3d::util::pool::ThreadPool;

fn main() {
    let pool = ThreadPool::global();
    let slabs = AccSlabs::global();
    let active = KernelArch::active();
    println!(
        "gemm_kernels: threads={} isa_detected={} kernel={} lanes={}",
        pool.threads(),
        KernelArch::best_supported().name(),
        active.name(),
        active.lanes()
    );
    // (M, K, R) shapes drawn from c3d layers at width 8 / 16x32x32 input.
    let shapes = [
        (16usize, 216usize, 8192usize),
        (64, 864, 2048),
        (64, 1728, 512),
    ];
    let tile = GemmTile::default();
    let mut group = BenchGroup::new("gemm_kernels").budget(budget_from_env(2000));
    let mut entries = Vec::new();
    for (m, k, r) in shapes {
        let w = Mat::random(m, k, 1);
        let p = Mat::random(k, r, 2);
        let gflop = (2 * m * k * r) as f64 / 1e9;
        let packed = PackedDense::pack(&w.data, m, k, tile.mr);
        let mut out = Mat::zeros(m, r);

        let t_untuned = group
            .bench(&format!("untuned/{m}x{k}x{r}"), || {
                out.data.fill(0.0);
                gemm::matmul_untuned(&w.data, m, &p, &mut out);
            })
            .median_s;
        // PR-1 baseline: blocked, scalar, strided weight loads.
        let t_pr1 = group
            .bench(&format!("pr1_strided/{m}x{k}x{r}"), || {
                out.data.fill(0.0);
                gemm::gemm_dense_unpacked(&w.data, m, &p, &mut out, tile, pool, slabs);
            })
            .median_s;
        let scalar_ctx =
            GemmCtx { tile, kernel: KernelArch::Scalar, cap: usize::MAX, pool, slabs };
        let t_packed_scalar = group
            .bench(&format!("packed_scalar/{m}x{k}x{r}"), || {
                gemm::gemm_dense_packed(&packed, &p, &mut out, &scalar_ctx);
            })
            .median_s;
        let simd_ctx = GemmCtx { kernel: active, ..scalar_ctx };
        let t_packed_simd = group
            .bench(&format!("packed_{}/{m}x{k}x{r}", active.name()), || {
                gemm::gemm_dense_packed(&packed, &p, &mut out, &simd_ctx);
            })
            .median_s;

        // Sanity: the SIMD path must be bit-identical to scalar.
        let mut a = Mat::zeros(m, r);
        gemm::gemm_dense_packed(&packed, &p, &mut a, &scalar_ctx);
        let mut b = Mat::zeros(m, r);
        gemm::gemm_dense_packed(&packed, &p, &mut b, &simd_ctx);
        assert_eq!(a.data, b.data, "SIMD output must be bit-identical to scalar");

        let speedup = t_pr1 / t_packed_simd;
        for (label, t) in [
            ("untuned", t_untuned),
            ("pr1_strided", t_pr1),
            ("packed_scalar", t_packed_scalar),
            ("packed_simd", t_packed_simd),
        ] {
            println!("gemm {m}x{k}x{r} {label}: {:.2} GFLOP/s", gflop / t);
        }
        println!("gemm {m}x{k}x{r} speedup packed_simd vs pr1: {speedup:.2}x");
        entries.push(format!(
            "    {{\"m\": {m}, \"k\": {k}, \"r\": {r}, \
             \"untuned_gflops\": {:.4}, \"pr1_gflops\": {:.4}, \
             \"packed_scalar_gflops\": {:.4}, \"packed_simd_gflops\": {:.4}, \
             \"speedup_packed_simd_vs_pr1\": {:.4}}}",
            gflop / t_untuned,
            gflop / t_pr1,
            gflop / t_packed_scalar,
            gflop / t_packed_simd,
            speedup
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"gemm_kernels\",\n  \"threads\": {},\n  \
         \"isa_detected\": \"{}\",\n  \"kernel\": \"{}\",\n  \
         \"simd_lanes\": {},\n  \"tile\": {{\"mr\": {}, \"rc\": {}, \"kc\": {}}},\n  \
         \"shapes\": [\n{}\n  ]\n}}\n",
        pool.threads(),
        KernelArch::best_supported().name(),
        active.name(),
        active.lanes(),
        tile.mr,
        tile.rc,
        tile.kc,
        entries.join(",\n")
    );
    let out = write_repo_json("BENCH_gemm_kernels.json", &json);
    println!("gemm_kernels: wrote {}", out.display());
}

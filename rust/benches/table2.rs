//! E2 (paper Table 2): end-to-end inference latency per framework class.
//!
//! Host columns measure the real executors on this machine (naive =
//! PyTorch-Mobile class, untuned = MNN class, rt3d dense, rt3d sparse);
//! the sim columns project onto the Snapdragon-865 cost model. The shape
//! to reproduce: rt3d-dense beats both baselines; rt3d-sparse beats dense
//! by ~the FLOPs pruning rate; GPU < CPU.

use rt3d::codegen;
use rt3d::device::{self, DeviceProfile, ExecutorClass};
use rt3d::executors::{EngineKind, NativeEngine};
use rt3d::model::Model;
use rt3d::tensor::Tensor5;
use rt3d::util::bench::{fmt_s, BenchGroup};
use std::time::Duration;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("c3d.manifest.json").exists() {
        eprintln!("table2: run `make artifacts` first");
        return;
    }
    let mut group = BenchGroup::new("table2").budget(Duration::from_secs(3));
    println!("== Table 2 reproduction (host measurements + device-sim projection)");
    for name in ["c3d", "r2plus1d", "s3d"] {
        let Ok(model) = Model::load(&dir, name) else { continue };
        let input = model.manifest.input;
        let clip =
            Tensor5::random([1, input[0], input[1], input[2], input[3]], 42);
        let engines = [
            ("naive", EngineKind::Naive, false),
            ("untuned", EngineKind::Untuned, false),
            ("rt3d_dense", EngineKind::Rt3d, false),
            ("rt3d_sparse", EngineKind::Rt3d, true),
        ];
        let mut medians = Vec::new();
        for (label, kind, sparse) in engines {
            let engine = NativeEngine::new(&model, kind, sparse);
            let bname = format!("{name}/{label}");
            let r = group.bench(&bname, || {
                let _ = engine.forward(&clip);
            });
            medians.push((label, r.median_s));
        }
        // Device-simulator projections (paper-scale absolute numbers).
        let convs_d = codegen::compile_model(&model, false);
        let convs_s = codegen::compile_model(&model, true);
        let cpu = DeviceProfile::mobile_cpu();
        let gpu = DeviceProfile::mobile_gpu();
        let (cpu_naive, _) =
            device::model_cost(&convs_d, ExecutorClass::Naive, &cpu, 1);
        let (cpu_d, _) = device::model_cost(&convs_d, ExecutorClass::Rt3d, &cpu, 1);
        let (cpu_s, _) = device::model_cost(&convs_s, ExecutorClass::Rt3d, &cpu, 1);
        let (gpu_d, _) = device::model_cost(&convs_d, ExecutorClass::Rt3d, &gpu, 1);
        let (gpu_s, _) = device::model_cost(&convs_s, ExecutorClass::Rt3d, &gpu, 1);
        println!(
            "table2/sim {name}: pytorch-cpu~{} rt3dCPU-D={} rt3dCPU-S={} \
             rt3dGPU-D={} rt3dGPU-S={} | speedup(sparseGPU vs naiveCPU)={:.1}x",
            fmt_s(cpu_naive),
            fmt_s(cpu_d),
            fmt_s(cpu_s),
            fmt_s(gpu_d),
            fmt_s(gpu_s),
            cpu_naive / gpu_s
        );
        let naive = medians[0].1;
        for (label, m) in &medians {
            println!(
                "table2/host {name}: {label} {} speedup_vs_naive={:.1}x",
                fmt_s(*m),
                naive / m
            );
        }
    }
}

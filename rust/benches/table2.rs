//! E2 (paper Table 2): end-to-end inference latency per framework class.
//!
//! Host columns measure the real executors on this machine (naive =
//! PyTorch-Mobile class, untuned = MNN class, rt3d dense, rt3d sparse);
//! the sim columns project onto the Snapdragon-865 cost model. The shape
//! to reproduce: rt3d-dense beats both baselines; rt3d-sparse beats dense
//! by ~the FLOPs pruning rate; GPU < CPU.
//!
//! Emits machine-readable `BENCH_table2.json` at the repo root (median/p95
//! latency per engine class, executor threads, GFLOP/s). Falls back to the
//! in-memory synthetic C3D model when `make artifacts` has not been run.

use rt3d::codegen::{self, KernelArch};
use rt3d::device::{self, DeviceProfile, ExecutorClass};
use rt3d::executors::{EngineKind, NativeEngine};
use rt3d::model::{Model, SyntheticC3d};
use rt3d::tensor::Tensor5;
use rt3d::util::bench::{budget_from_env, fmt_s, write_repo_json, BenchGroup};
use rt3d::util::pool::ThreadPool;

struct Row {
    model: String,
    engine: &'static str,
    median_ms: f64,
    p95_ms: f64,
    gflops: f64,
}

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let threads = ThreadPool::from_env().threads();
    let mut group = BenchGroup::new("table2").budget(budget_from_env(3000));
    println!(
        "== Table 2 reproduction (host measurements + device-sim projection, \
         {threads} executor threads, isa_detected={} kernel={})",
        KernelArch::best_supported().name(),
        KernelArch::active().name()
    );
    let mut rows: Vec<Row> = Vec::new();
    for name in ["c3d", "r2plus1d", "s3d"] {
        let model = if dir.join(format!("{name}.manifest.json")).exists() {
            match Model::load(&dir, name) {
                Ok(m) => m,
                Err(_) => continue,
            }
        } else if name == "c3d" {
            println!("table2: artifacts missing — using the synthetic C3D-shaped model");
            Model::synthetic_c3d(SyntheticC3d::default())
        } else {
            continue;
        };
        let input = model.manifest.input;
        let clip =
            Tensor5::random([1, input[0], input[1], input[2], input[3]], 42);
        let engines = [
            ("naive", EngineKind::Naive, false),
            ("untuned", EngineKind::Untuned, false),
            ("rt3d_dense", EngineKind::Rt3d, false),
            ("rt3d_sparse", EngineKind::Rt3d, true),
        ];
        let mut medians = Vec::new();
        for (label, kind, sparse) in engines {
            let engine = NativeEngine::builder(&model)
                .kind(kind)
                .sparsity(sparse)
                .threads(threads)
                .build();
            let bname = format!("{}/{label}", model.manifest.model);
            let r = group.bench(&bname, || {
                let _ = engine.forward(&clip);
            });
            medians.push((label, r.median_s));
            rows.push(Row {
                model: model.manifest.model.clone(),
                engine: label,
                median_ms: r.median_s * 1e3,
                p95_ms: r.p95_s * 1e3,
                gflops: engine.conv_flops() as f64 / r.median_s / 1e9,
            });
        }
        // Device-simulator projections (paper-scale absolute numbers).
        let convs_d = codegen::compile_model(&model, false);
        let convs_s = codegen::compile_model(&model, true);
        let cpu = DeviceProfile::mobile_cpu();
        let gpu = DeviceProfile::mobile_gpu();
        let (cpu_naive, _) =
            device::model_cost(&convs_d, ExecutorClass::Naive, &cpu, 1);
        let (cpu_d, _) = device::model_cost(&convs_d, ExecutorClass::Rt3d, &cpu, 1);
        let (cpu_s, _) = device::model_cost(&convs_s, ExecutorClass::Rt3d, &cpu, 1);
        let (gpu_d, _) = device::model_cost(&convs_d, ExecutorClass::Rt3d, &gpu, 1);
        let (gpu_s, _) = device::model_cost(&convs_s, ExecutorClass::Rt3d, &gpu, 1);
        println!(
            "table2/sim {name}: pytorch-cpu~{} rt3dCPU-D={} rt3dCPU-S={} \
             rt3dGPU-D={} rt3dGPU-S={} | speedup(sparseGPU vs naiveCPU)={:.1}x",
            fmt_s(cpu_naive),
            fmt_s(cpu_d),
            fmt_s(cpu_s),
            fmt_s(gpu_d),
            fmt_s(gpu_s),
            cpu_naive / gpu_s
        );
        let naive = medians[0].1;
        for (label, m) in &medians {
            println!(
                "table2/host {name}: {label} {} speedup_vs_naive={:.1}x",
                fmt_s(*m),
                naive / m
            );
        }
    }

    // --- Machine-readable output ---------------------------------------
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"table2\",\n");
    json.push_str(&format!("  \"threads\": {threads},\n"));
    json.push_str(&format!(
        "  \"isa_detected\": \"{}\",\n",
        KernelArch::best_supported().name()
    ));
    json.push_str(&format!("  \"kernel\": \"{}\",\n", KernelArch::active().name()));
    json.push_str("  \"entries\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"model\": \"{}\", \"engine\": \"{}\", \"median_ms\": {:.4}, \"p95_ms\": {:.4}, \"gflops\": {:.4}}}{}\n",
            r.model,
            r.engine,
            r.median_ms,
            r.p95_ms,
            r.gflops,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let out = write_repo_json("BENCH_table2.json", &json);
    println!("table2: wrote {}", out.display());
}

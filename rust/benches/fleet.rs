//! Fleet benchmark: open-loop trace replay against a real 2-worker
//! `rt3d fleet` — supervisor + crash-isolated worker processes — over
//! loopback TCP.
//!
//! What is measured and gated (DESIGN.md §Perf):
//! * the scheduled-arrival latency tail (p50/p99/p99.9) of a bursty
//!   Poisson trace proxied through the supervisor to two workers — the
//!   number the fleet exists to keep bounded when a worker dies;
//! * the shed rate under that burst (admission control behaving, not
//!   collapsing);
//! * the serving contract: nothing lost, nothing unanswered, no failed
//!   responses, and a graceful Shutdown -> Bye -> exit-0 drain.
//!
//! Emits `BENCH_fleet.json` at the repo root; `.github/workflows/ci.yml`
//! compares it against the committed baseline via
//! `scripts/check_bench_regression.py`. The workers run the synthetic
//! default C3D model (`--synthetic default`) so the bench needs no
//! artifacts and the clip geometry is fixed.

use rt3d::coordinator::net::fetch_metrics;
use rt3d::coordinator::{Frame, NetClient};
use rt3d::model::SyntheticC3d;
use rt3d::util::bench::{budget_from_env, write_repo_json};
use rt3d::workload::{replay, Modulation, ReplayConfig};
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

const WORKERS: usize = 2;

/// Read supervisor stdout until the public listener and every worker has
/// announced itself; returns the public address and a drain thread that
/// keeps echoing the remaining supervisor log.
fn await_fleet_ready(child: &mut Child) -> (String, std::thread::JoinHandle<()>) {
    let stdout = child.stdout.take().expect("fleet stdout piped");
    let mut lines = BufReader::new(stdout).lines();
    let mut public = None;
    let mut ready = 0usize;
    for line in lines.by_ref() {
        let line = line.expect("fleet stdout readable");
        println!("[fleet] {line}");
        if let Some(addr) = line.strip_prefix("listening on ") {
            public = Some(addr.trim().to_string());
        }
        if line.starts_with("fleet: worker") && line.contains(" ready at ") {
            ready += 1;
        }
        if public.is_some() && ready >= WORKERS {
            break;
        }
    }
    let public = public.expect("fleet exited before announcing its listener");
    let drain = std::thread::spawn(move || {
        for line in lines.map_while(|l| l.ok()) {
            println!("[fleet] {line}");
        }
    });
    (public, drain)
}

fn main() {
    let budget = budget_from_env(2000);
    // Scale the trace to the budget: the replay wall-clock is the trace
    // duration (requests / rate), independent of server speed.
    let (requests, rate_hz) = if budget < Duration::from_millis(1000) {
        (40usize, 40.0)
    } else {
        (160usize, 40.0)
    };

    let mut child = Command::new(env!("CARGO_BIN_EXE_rt3d"))
        .args([
            "fleet",
            "-n",
            "2",
            "--listen",
            "127.0.0.1:0",
            "--allow-shutdown",
            "--synthetic",
            "default",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .stdin(Stdio::null())
        .spawn()
        .expect("spawn rt3d fleet");
    let (addr, drain) = await_fleet_ready(&mut child);
    println!("fleet: supervisor up at {addr}, {WORKERS} workers ready");

    // Bursty open-loop load: 3x the base rate for a quarter of every
    // second — the supervisor must keep the tail bounded while balancing
    // across both workers.
    let synth = SyntheticC3d::default();
    let cfg = ReplayConfig {
        rate_hz,
        requests,
        seed: 11,
        modulation: Modulation::Bursty { period_s: 1.0, duty: 0.25, factor: 3.0 },
        sessions: 4,
        frames: synth.frames,
        size: synth.size,
        ..ReplayConfig::new(addr.clone())
    };
    let r = replay(&cfg).expect("trace replay against the fleet");
    println!(
        "fleet replay: sent={} ok={} failed={} shed={} lost={} unanswered={} p50={:.1}ms p99={:.1}ms p99.9={:.1}ms shed_rate={:.3} offered={:.1}/s achieved={:.1}/s",
        r.sent, r.ok, r.failed, r.shed, r.lost, r.unanswered,
        r.p50_ms, r.p99_ms, r.p999_ms, r.shed_rate,
        r.offered_rate_hz, r.achieved_rate_hz,
    );
    assert_eq!(r.sent, requests, "every request reached a live connection");
    assert_eq!(r.lost, 0, "no connection may die in a kill-free run");
    assert_eq!(r.unanswered, 0, "exactly-one-response violated");
    assert_eq!(r.failed, 0, "no failed responses in a fault-free run");
    assert!(r.ok > 0, "no request executed successfully");

    // Aggregated supervisor metrics: both workers live, none restarted.
    let metrics = fetch_metrics(addr.as_str()).expect("GET /metrics on the supervisor");
    for needle in
        ["rt3d_workers_live 2", "rt3d_worker_restarts_total 0", "rt3d_requests_total"]
    {
        assert!(metrics.contains(needle), "/metrics missing `{needle}`:\n{metrics}");
    }
    println!("fleet metrics: workers_live=2 restarts_total=0 confirmed");

    // Graceful drain: Shutdown fans out, workers flush, supervisor exits 0.
    let mut client = NetClient::connect(addr.as_str()).expect("connect for shutdown");
    client.send(&Frame::Shutdown).expect("send Shutdown");
    match client.recv().expect("recv after Shutdown") {
        Frame::Bye => println!("fleet: shutdown acknowledged"),
        other => panic!("expected Bye after Shutdown, got {other:?}"),
    }
    let status = child.wait().expect("wait for fleet supervisor");
    drain.join().ok();
    assert!(status.success(), "fleet supervisor must drain to exit 0, got {status}");

    // --- Machine-readable output ---------------------------------------
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"fleet\",\n");
    json.push_str(&format!("  \"workers\": {WORKERS},\n"));
    json.push_str(&format!("  \"sessions\": {},\n", cfg.sessions));
    json.push_str(&format!("  \"requests\": {requests},\n"));
    json.push_str(&format!("  \"rate_hz\": {rate_hz:.1},\n"));
    json.push_str("  \"modulation\": \"bursty period=1s duty=0.25 factor=3\",\n");
    json.push_str(&format!("  \"fleet_p50_ms\": {:.4},\n", r.p50_ms));
    json.push_str(&format!("  \"fleet_p99_ms\": {:.4},\n", r.p99_ms));
    json.push_str(&format!("  \"fleet_p999_ms\": {:.4},\n", r.p999_ms));
    json.push_str(&format!("  \"fleet_shed_rate\": {:.4},\n", r.shed_rate));
    json.push_str(&format!("  \"ok\": {},\n", r.ok));
    json.push_str(&format!("  \"shed\": {},\n", r.shed));
    json.push_str(&format!("  \"offered_rate_hz\": {:.4},\n", r.offered_rate_hz));
    json.push_str(&format!("  \"achieved_rate_hz\": {:.4},\n", r.achieved_rate_hz));
    json.push_str("  \"graceful_exit\": true\n");
    json.push_str("}\n");
    let out = write_repo_json("BENCH_fleet.json", &json);
    println!("fleet: wrote {}", out.display());
}

//! E5: "inference speedup approaches the FLOPs pruning rate" (paper §3/§5.2).
//!
//! Sweeps KGS keep-fraction on a representative conv layer; the series to
//! reproduce is latency ∝ density (speedup ≈ pruning rate).

use rt3d::codegen::{compile_conv_sparse, Scheme};
use rt3d::executors;
use rt3d::model::{ConvLayer, TensorRef, WeightRefs};
use rt3d::tensor::{Conv3dGeometry, Mat, Tensor5};
use rt3d::util::bench::BenchGroup;
use std::time::Duration;

fn main() {
    println!(
        "sparsity_sweep: {} executor threads (RT3D_THREADS)",
        rt3d::util::pool::ThreadPool::global().threads()
    );
    let (m, ch) = (64usize, 64usize);
    let dummy = TensorRef { offset: 0, shape: vec![], dtype: "f32".into() };
    let layer = ConvLayer {
        name: "sweep".into(),
        in_ch: ch,
        out_ch: m,
        kernel: [3, 3, 3],
        stride: [1, 1, 1],
        padding: [1, 1, 1],
        relu: false,
        weights: WeightRefs { w: dummy.clone(), b: dummy },
        weights_sparse: None,
        unit_mask: None,
        quant: None,
    };
    let geom = Conv3dGeometry {
        in_ch: ch,
        out_ch: m,
        kernel: [3, 3, 3],
        stride: [1, 1, 1],
        padding: [1, 1, 1],
        in_spatial: [8, 16, 16],
    };
    let w = Tensor5::random([m, ch, 3, 3, 3], 1).data;
    let x = Tensor5::random([1, ch, 8, 16, 16], 2);
    let pt = executors::im2col_t(&x, &geom);
    let (pp, qq) = (16usize, 16usize);

    let mut group = BenchGroup::new("sparsity_sweep").budget(Duration::from_secs(2));
    let mut series = Vec::new();
    for keep in [27usize, 14, 9, 7, 5, 3] {
        let mut mask = vec![false; pp * qq * 27];
        for g in 0..pp * qq {
            for i in 0..keep {
                mask[g * 27 + (i * 5 + g) % 27] = true;
            }
        }
        let cc = compile_conv_sparse(
            &layer,
            &geom,
            &w,
            vec![0.0; m],
            &mask,
            Scheme::Kgs,
            4,
            4,
        );
        let rate = 27.0 / keep as f64;
        let mut out = Mat::zeros(m, pt.cols);
        let r = group.bench(&format!("rate_{rate:.1}x"), || {
            executors::run_compiled_conv(&cc, &pt, &mut out)
        });
        series.push((rate, r.median_s));
    }
    let dense = series[0].1;
    println!("\nsparsity_sweep series (speedup vs FLOPs rate — paper claim: ~equal):");
    println!("{:>8} {:>10} {:>10}", "rate", "speedup", "efficiency");
    for (rate, t) in &series {
        let speedup = dense / t;
        println!("{:>7.1}x {:>9.2}x {:>9.0}%", rate, speedup, 100.0 * speedup / rate);
    }
}

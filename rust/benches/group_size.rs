//! E7: group-size ablation (paper §3: g_N = 4, g_M = 4 or 8 preferred).
//!
//! Times a KGS-compacted layer at fixed 3x pruning across group sizes.
//! Expected shape: tiny groups (2x2) pay gather overhead; 4x4 / 8x4 reach
//! the knee; bigger groups gain little speed (and cost accuracy in Table 1).

use rt3d::codegen::tuner::time_group_size;
use rt3d::util::bench::BenchGroup;
use std::time::Duration;

fn main() {
    println!(
        "group_size: {} executor threads (RT3D_THREADS)",
        rt3d::util::pool::ThreadPool::global().threads()
    );
    let mut group = BenchGroup::new("group_size")
        .budget(Duration::from_secs(2))
        .max_iters(20);
    let mut rows = Vec::new();
    for (g_m, g_n) in [
        (2usize, 2usize),
        (2, 4),
        (4, 2),
        (4, 4),
        (8, 4),
        (4, 8),
        (8, 8),
        (16, 16),
    ] {
        let r = group.bench(&format!("g{g_m}x{g_n}"), || {
            let _ = time_group_size(64, 64, [8, 16, 16], g_m, g_n, 1.0 / 3.0, 1);
        });
        rows.push(((g_m, g_n), r.median_s));
    }
    let best = rows
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "\ngroup_size verdict: fastest {}x{} (paper prefers 4x4 / 8x4 to match SIMD width)",
        best.0 .0, best.0 .1
    );
}

//! Coordinator benchmark: serving throughput/latency across batch caps —
//! validates that the L3 layer adds negligible overhead on top of the
//! executor (DESIGN.md §Perf: coordinator < 5% of end-to-end latency).

use rt3d::coordinator::{BatcherConfig, Server, ServerConfig};
use rt3d::executors::{EngineKind, NativeEngine};
use rt3d::model::Model;
use rt3d::tensor::Tensor5;
use rt3d::util::bench::fmt_s;
use rt3d::workload;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("c3d.manifest.json").exists() {
        eprintln!("serving: run `make artifacts` first");
        return;
    }
    let model = Model::load(&dir, "c3d").unwrap();
    let input = model.manifest.input;
    let n = 24;

    // Raw engine latency (no coordinator).
    let engine = NativeEngine::new(&model, EngineKind::Rt3d, true);
    let clip = Tensor5::random([1, input[0], input[1], input[2], input[3]], 1);
    let t0 = Instant::now();
    for _ in 0..4 {
        let _ = engine.forward(&clip);
    }
    let raw = t0.elapsed().as_secs_f64() / 4.0;
    println!("serving raw-engine latency: {}", fmt_s(raw));

    for max_batch in [1usize, 2, 4, 8] {
        let engine = Arc::new(NativeEngine::new(&model, EngineKind::Rt3d, true));
        let server = Server::start(
            engine,
            ServerConfig {
                batcher: BatcherConfig {
                    max_batch,
                    max_wait: std::time::Duration::from_millis(5),
                },
                queue_depth: 64,
            },
        );
        let t0 = Instant::now();
        for i in 0..n {
            server.submit(
                workload::make_clip(i % 8, i as u64, input[1], input[2]),
                Some(i % 8),
            );
        }
        for _ in 0..n {
            server.responses.recv().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = server.shutdown();
        let lat = m.latency();
        println!(
            "serving max_batch={max_batch}: {:.2} req/s p50={} p99={} mean_batch={:.2} overhead_vs_raw={:.1}%",
            n as f64 / wall,
            fmt_s(lat.p50_s),
            fmt_s(lat.p99_s),
            m.mean_batch(),
            // queueing-free single-batch overhead estimate
            100.0 * ((wall / n as f64) * m.mean_batch() / raw - 1.0)
        );
    }
}

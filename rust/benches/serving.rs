//! Coordinator benchmark: serving throughput/latency across batch caps,
//! executor thread counts and serving-worker counts.
//!
//! Three claims are validated here (DESIGN.md §Perf):
//! * the coordinator adds negligible overhead on top of the executor;
//! * the parallel execution pipeline scales: N executor threads beat one
//!   thread on the C3D-shaped workload while producing **bit-identical**
//!   logits (the disjoint-output-rows invariant, see `util::pool`);
//! * the serving pipeline scales across workers: under an open-loop
//!   saturating load, N batch-execution workers (each a forked handle
//!   over one shared compiled core, splitting the same core budget) beat
//!   one worker on saturation throughput (clips/s).
//!
//! Emits machine-readable `BENCH_serving.json` at the repo root
//! (p50/p95 latency, threads, GFLOP/s, workers sweep) so the perf
//! trajectory is tracked across PRs; `.github/workflows/ci.yml` compares
//! it against the committed baseline. Falls back to the in-memory
//! synthetic C3D model when `make artifacts` has not been run.

use rt3d::codegen::KernelArch;
use rt3d::coordinator::{
    Admission, Deployment, Frame, NetClient, NetServer, NetServerConfig,
    Outcome, Policy, Router, Server, ServerConfig,
};
use rt3d::executors::NativeEngine;
use rt3d::model::{Model, SyntheticC3d};
use rt3d::tensor::Tensor5;
use rt3d::util::bench::{budget_from_env, fmt_s, write_repo_json};
use rt3d::util::pool::ThreadPool;
use rt3d::workload;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Latency samples for one engine: (p50_s, p95_s, samples).
fn time_forward(
    engine: &NativeEngine,
    clip: &Tensor5,
    budget: Duration,
) -> (f64, f64, usize) {
    let _ = engine.forward(clip); // warm-up (also grows the arena)
    let t0 = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < 5 || (t0.elapsed() < budget && samples.len() < 200) {
        let s = Instant::now();
        let _ = engine.forward(clip);
        samples.push(s.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    (samples[n / 2], samples[((n as f64 - 1.0) * 0.95).round() as usize], n)
}

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let model = if dir.join("c3d.manifest.json").exists() {
        Model::load(&dir, "c3d").unwrap()
    } else {
        println!("serving: artifacts missing — using the synthetic C3D-shaped model");
        Model::synthetic_c3d(SyntheticC3d::default())
    };
    let input = model.manifest.input;
    let clip = Tensor5::random([1, input[0], input[1], input[2], input[3]], 1);
    let threads = ThreadPool::from_env().threads();
    let budget = budget_from_env(2000);

    let kernel = KernelArch::active();
    println!(
        "serving: isa_detected={} kernel={} lanes={}",
        KernelArch::best_supported().name(),
        kernel.name(),
        kernel.lanes()
    );

    // --- Thread scaling + bit-identical parity -------------------------
    let build = |threads: usize| {
        NativeEngine::builder(&model).sparsity(true).threads(threads).build()
    };
    let eng1 = build(1);
    let engn = build(threads);
    let l1 = eng1.forward(&clip);
    let ln = engn.forward(&clip);
    assert_eq!(
        l1.data, ln.data,
        "multi-threaded logits must be bit-identical to single-threaded"
    );
    println!("serving parity: logits bit-identical at 1 vs {threads} threads");
    // SIMD-on vs scalar fallback on the same ISA path must also agree
    // bit for bit (the kernels use mul+add lanes, never fused FMA).
    if kernel != KernelArch::Scalar {
        let scal = NativeEngine::builder(&model)
            .sparsity(true)
            .threads(threads)
            .kernel(KernelArch::Scalar)
            .build();
        assert_eq!(
            scal.forward(&clip).data,
            ln.data,
            "SIMD logits must be bit-identical to scalar"
        );
        println!(
            "serving parity: logits bit-identical {} vs scalar kernel",
            kernel.name()
        );
    }
    let (p50_1, p95_1, n1) = time_forward(&eng1, &clip, budget);
    let (p50_n, p95_n, nn) = time_forward(&engn, &clip, budget);
    let speedup = p50_1 / p50_n;
    let gflops = engn.conv_flops() as f64 / p50_n / 1e9;
    println!(
        "serving raw-engine latency: 1t p50={} (n={n1})  {threads}t p50={} p95={} (n={nn})  speedup={speedup:.2}x  {gflops:.2} GFLOP/s",
        fmt_s(p50_1),
        fmt_s(p50_n),
        fmt_s(p95_n),
    );

    // --- Coordinator overhead across batch caps ------------------------
    let n = 24;
    let mut served = Vec::new();
    for max_batch in [1usize, 2, 4, 8] {
        let engine = Arc::new(build(threads));
        let server = Server::start(
            engine,
            ServerConfig::new()
                .max_batch(max_batch)
                .max_wait(std::time::Duration::from_millis(5))
                .queue_depth(64)
                .workers(1),
        );
        let responses = server.take_responses().expect("responses");
        let t0 = Instant::now();
        for i in 0..n {
            server
                .submit(workload::make_clip(i % 8, i as u64, input[1], input[2]), Some(i % 8))
                .unwrap();
        }
        for _ in 0..n {
            responses.recv().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = server.shutdown();
        let lat = m.latency();
        println!(
            "serving max_batch={max_batch}: {:.2} req/s p50={} p95={} p99={} mean_batch={:.2} overhead_vs_raw={:.1}%",
            n as f64 / wall,
            fmt_s(lat.p50_s),
            fmt_s(lat.p95_s),
            fmt_s(lat.p99_s),
            m.mean_batch(),
            // queueing-free single-batch overhead estimate
            100.0 * ((wall / n as f64) * m.mean_batch() / p50_n - 1.0)
        );
        served.push((max_batch, n as f64 / wall, lat.p50_s, lat.p95_s, m.mean_batch()));
    }

    // --- Worker scaling: open-loop saturation throughput ----------------
    // Each configuration splits the same core budget: `workers` serving
    // threads x (threads / workers) executor threads per forked handle.
    // The generator offers load as fast as the bounded ingress queue
    // accepts (open loop until back-pressure), so the measured completion
    // rate is the pipeline's saturation throughput.
    let mut worker_counts = vec![1usize];
    if threads >= 2 {
        worker_counts.push(2);
    }
    if threads > 2 {
        worker_counts.push(threads);
    }
    let sat_n = if budget < Duration::from_millis(1000) { 32 } else { 96 };
    // Pre-generate the clip set once; submits clone from it so clip
    // synthesis cost stays out of the measured window.
    let clip_set: Vec<Tensor5> = (0..8)
        .map(|i| workload::make_clip(i % 8, 7 + i as u64, input[1], input[2]))
        .collect();
    let mut sweep = Vec::new();
    for &wk in &worker_counts {
        let per_worker_threads = (threads / wk).max(1);
        let engine = Arc::new(build(per_worker_threads));
        let server = Server::start(
            engine,
            ServerConfig::new()
                .max_batch(4)
                .max_wait(std::time::Duration::from_millis(2))
                .queue_depth(16)
                .workers(wk),
        );
        let responses = server.take_responses().expect("responses");
        let t0 = Instant::now();
        std::thread::scope(|s| {
            // Open-loop generator: offers the whole trace back-to-back;
            // blocks only when the pipeline is saturated.
            s.spawn(|| {
                for i in 0..sat_n {
                    server
                        .submit(clip_set[i % clip_set.len()].clone(), Some(i % 8))
                        .unwrap();
                }
            });
            for _ in 0..sat_n {
                responses.recv().unwrap();
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let clips_s = sat_n as f64 / wall;
        let m = server.shutdown();
        let lat = m.latency();
        println!(
            "serving workers={wk} ({per_worker_threads} threads each): {clips_s:.2} clips/s p95={} mean_batch={:.2} batches/worker={:?}",
            fmt_s(lat.p95_s),
            m.mean_batch(),
            m.worker_batches(),
        );
        sweep.push((wk, per_worker_threads, clips_s, lat.p50_s, lat.p95_s));
    }
    let base_clips_s = sweep[0].2;
    let best = sweep
        .iter()
        .copied()
        .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
        .unwrap();
    let workers_speedup = best.2 / base_clips_s;
    println!(
        "serving saturation: workers=1 {:.2} clips/s, best workers={} {:.2} clips/s ({workers_speedup:.2}x)",
        base_clips_s,
        best.0,
        best.2
    );

    // --- Admission control under overload -------------------------------
    // Offer the whole trace through the non-blocking front door against a
    // deliberately tiny pipeline (ingress depth 4, one worker): try_submit
    // must shed the excess synchronously instead of blocking, every
    // accepted request must still complete, and the shed/failed rates are
    // tracked in the bench JSON (a fault-free run must report
    // failed_rate = 0).
    let engine = Arc::new(build(threads));
    let server = Server::start(
        engine,
        ServerConfig::new()
            .max_batch(4)
            .max_wait(std::time::Duration::from_millis(2))
            .queue_depth(4)
            .workers(1),
    );
    let responses = server.take_responses().expect("responses");
    let offered = sat_n;
    let mut accepted = 0usize;
    let t0 = Instant::now();
    for i in 0..offered {
        match server
            .try_submit(clip_set[i % clip_set.len()].clone(), Some(i % 8), None)
            .unwrap()
        {
            Admission::Accepted(_) => accepted += 1,
            Admission::Shed(_) => {}
        }
    }
    let offer_wall = t0.elapsed().as_secs_f64();
    for _ in 0..accepted {
        responses.recv().unwrap();
    }
    let m = server.shutdown();
    let snap = m.snapshot();
    assert_eq!(snap.ok, accepted, "every admitted request completed");
    assert_eq!(snap.shed, offered - accepted, "shed accounting");
    let shed_rate = snap.shed_rate();
    let failed_rate = snap.failed_rate();
    assert_eq!(failed_rate, 0.0, "fault-free run must not fail batches");
    println!(
        "serving overload: offered={offered} in {:.1}ms accepted={accepted} shed={} shed_rate={shed_rate:.3} failed_rate={failed_rate:.3}",
        offer_wall * 1e3,
        snap.shed,
    );

    // --- Network loopback: the wire front door over the same pipeline ---
    // A closed-loop client with a bounded in-flight window (below the
    // ingress queue depth, so nothing sheds) streams the trace through
    // `NetServer` on 127.0.0.1 — measuring what the TCP framing, demux and
    // per-connection writer add on top of the in-process pipeline. The
    // per-request latency comes off the response frames (server-side
    // clock), the throughput from the wall.
    let engine = Arc::new(build(threads));
    let router = Arc::new(Router::new(Policy::BestAccuracy));
    router.add_deployment(
        "c3d",
        Deployment {
            name: "bench".into(),
            engine,
            expected_latency_s: 0.05,
            accuracy: None,
        },
        ServerConfig::new()
            .max_batch(4)
            .max_wait(std::time::Duration::from_millis(2))
            .queue_depth(16)
            .workers(1),
    );
    let net =
        NetServer::bind("127.0.0.1:0", router.clone(), NetServerConfig::new(), None)
            .unwrap();
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    let net_n = sat_n;
    let window = 8;
    let mut net_lat = Vec::with_capacity(net_n);
    let (mut submitted, mut received) = (0usize, 0usize);
    let t0 = Instant::now();
    while received < net_n {
        while submitted < net_n && submitted - received < window {
            client
                .request(
                    submitted as u64,
                    "c3d",
                    clip_set[submitted % clip_set.len()].clone(),
                    Some((submitted % 8) as u32),
                    0,
                )
                .unwrap();
            submitted += 1;
        }
        match client.recv().unwrap() {
            Frame::Response { outcome, latency_us, .. } => {
                assert_eq!(outcome, Outcome::Ok, "loopback request not served");
                net_lat.push(latency_us as f64 / 1e6);
                received += 1;
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    let net_wall = t0.elapsed().as_secs_f64();
    let net_clips_s = net_n as f64 / net_wall;
    net_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let net_p95_s = net_lat[((net_lat.len() as f64 - 1.0) * 0.95).round() as usize];
    net.shutdown();
    if let Ok(r) = Arc::try_unwrap(router) {
        r.shutdown();
    }
    println!(
        "serving net loopback: {net_clips_s:.2} clips/s p95={} ({net_n} clips over TCP, window {window})",
        fmt_s(net_p95_s),
    );

    // --- Machine-readable output ---------------------------------------
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"serving\",\n");
    json.push_str(&format!("  \"model\": \"{}\",\n", model.manifest.model));
    json.push_str(&format!("  \"threads\": {threads},\n"));
    json.push_str(&format!(
        "  \"isa_detected\": \"{}\",\n",
        KernelArch::best_supported().name()
    ));
    json.push_str(&format!("  \"kernel\": \"{}\",\n", kernel.name()));
    json.push_str(&format!("  \"simd_lanes\": {},\n", kernel.lanes()));
    json.push_str(&format!("  \"p50_ms\": {:.4},\n", p50_n * 1e3));
    json.push_str(&format!("  \"p95_ms\": {:.4},\n", p95_n * 1e3));
    json.push_str(&format!("  \"p50_ms_1t\": {:.4},\n", p50_1 * 1e3));
    json.push_str(&format!("  \"p95_ms_1t\": {:.4},\n", p95_1 * 1e3));
    json.push_str(&format!("  \"speedup_vs_1t\": {speedup:.4},\n"));
    json.push_str(&format!("  \"gflops\": {gflops:.4},\n"));
    json.push_str("  \"bit_identical_logits\": true,\n");
    json.push_str(&format!("  \"shed_rate\": {shed_rate:.4},\n"));
    json.push_str(&format!("  \"failed_rate\": {failed_rate:.4},\n"));
    json.push_str(&format!("  \"net_clips_per_s\": {net_clips_s:.4},\n"));
    json.push_str(&format!("  \"net_p95_ms\": {:.4},\n", net_p95_s * 1e3));
    json.push_str(&format!("  \"saturation_clips_per_s\": {:.4},\n", best.2));
    json.push_str(&format!("  \"workers_best\": {},\n", best.0));
    json.push_str(&format!("  \"workers_speedup\": {workers_speedup:.4},\n"));
    json.push_str("  \"workers\": [\n");
    for (i, (wk, tpw, clips_s, p50, p95)) in sweep.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"workers\": {wk}, \"threads_per_worker\": {tpw}, \"clips_per_s\": {clips_s:.4}, \"p50_ms\": {:.4}, \"p95_ms\": {:.4}}}{}\n",
            p50 * 1e3,
            p95 * 1e3,
            if i + 1 < sweep.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"server\": [\n");
    for (i, (mb, rps, p50, p95, meanb)) in served.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"max_batch\": {mb}, \"req_per_s\": {rps:.4}, \"p50_ms\": {:.4}, \"p95_ms\": {:.4}, \"mean_batch\": {meanb:.4}}}{}\n",
            p50 * 1e3,
            p95 * 1e3,
            if i + 1 < served.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let out = write_repo_json("BENCH_serving.json", &json);
    println!("serving: wrote {}", out.display());
}

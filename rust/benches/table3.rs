//! E3 (paper Table 3, extended): the four-scheme accuracy-vs-latency
//! frontier — Vanilla, KGS, Pattern (PatDNN) and BlockPunched (PCONV/GRIM)
//! through the one compiler/executor pipeline, at matched FLOP pruning
//! rates (~3x on a C3D-class layer).
//!
//! Two measurement tiers, both published into `BENCH_table3.json` (gated
//! by `scripts/check_bench_regression.py` like every other bench):
//!
//! * per-scheme single-layer latency + effective GFLOP/s on one
//!   conv shape (`<scheme>_ms` / `<scheme>_gflops`) — the kernel-level
//!   frontier;
//! * end-to-end synthetic-C3D forward latency for the schemes with
//!   synthetic model variants (`<scheme>_e2e_ms`) — the deployment-level
//!   frontier (Vanilla has no synthetic variant; its row is layer-level
//!   only, like the paper's per-layer Table 3 measurements).
//!
//! The accuracy axis comes from the python side (pruned-model eval
//! accuracy in the exported manifest); at matched FLOP rates the schemes
//! differ in *achievable accuracy* (KGS/Pattern > Vanilla per the paper
//! family) while this bench measures what each costs in latency.

use rt3d::codegen::{compile_conv_sparse, Scheme};
use rt3d::executors::{self, NativeEngine};
use rt3d::model::{ConvLayer, Model, SyntheticC3d, TensorRef, WeightRefs};
use rt3d::tensor::{Conv3dGeometry, Mat, Tensor5};
use rt3d::util::bench::{budget_from_env, write_repo_json, BenchGroup};

fn conv(m: usize, c: usize) -> (ConvLayer, Conv3dGeometry) {
    let dummy = TensorRef { offset: 0, shape: vec![], dtype: "f32".into() };
    let layer = ConvLayer {
        name: "bench".into(),
        in_ch: c,
        out_ch: m,
        kernel: [3, 3, 3],
        stride: [1, 1, 1],
        padding: [1, 1, 1],
        relu: true,
        weights: WeightRefs { w: dummy.clone(), b: dummy },
        weights_sparse: None,
        unit_mask: None,
        quant: None,
    };
    let geom = Conv3dGeometry {
        in_ch: c,
        out_ch: m,
        kernel: [3, 3, 3],
        stride: [1, 1, 1],
        padding: [1, 1, 1],
        in_spatial: [8, 16, 16],
    };
    (layer, geom)
}

/// KGS: keep `keep` of 27 tap locations per (4x4) kernel group.
fn kgs_mask(pp: usize, qq: usize, keep: usize) -> Vec<bool> {
    let mut mask = vec![false; pp * qq * 27];
    for g in 0..pp * qq {
        for i in 0..keep {
            mask[g * 27 + (i * 5 + g) % 27] = true;
        }
    }
    mask
}

/// Vanilla: keep `keep` of `qq` channel groups per filter-group row.
fn vanilla_mask(pp: usize, qq: usize, keep: usize) -> Vec<bool> {
    let mut mask = vec![false; pp * qq];
    for p in 0..pp {
        for i in 0..keep {
            mask[p * qq + (i * 3 + p) % qq] = true;
        }
    }
    mask
}

/// Pattern: per-element mask; each kernel keeps one of 8 dictionary
/// patterns of `keep` taps (gcd(7, 27) = 1 spreads them distinctly).
fn pattern_mask(m: usize, c: usize, keep: usize) -> Vec<bool> {
    let mut mask = vec![false; m * c * 27];
    for mi in 0..m {
        for ci in 0..c {
            let pat = (mi * 5 + ci * 3) % 8;
            for i in 0..keep {
                mask[(mi * c + ci) * 27 + (i * 7 + pat) % 27] = true;
            }
        }
    }
    mask
}

/// BlockPunched: per 4-filter block, keep `keep` of every kernel's 27
/// taps — one shared kept-column map per block.
fn block_punched_mask(m: usize, c: usize, keep: usize) -> Vec<bool> {
    let pp = m.div_ceil(4);
    let k = c * 27;
    let mut mask = vec![false; pp * k];
    for p in 0..pp {
        for (ki, v) in mask[p * k..(p + 1) * k].iter_mut().enumerate() {
            *v = ((ki % 27) * 7 + p) % 27 < keep;
        }
    }
    mask
}

fn main() {
    let threads = rt3d::util::pool::ThreadPool::global().threads();
    println!("table3: {threads} executor threads (RT3D_THREADS)");
    let (m, ch) = (64usize, 64usize);
    let (layer, geom) = conv(m, ch);
    let w = Tensor5::random([m, ch, 3, 3, 3], 1).data;
    let x = Tensor5::random([1, ch, 8, 16, 16], 2);
    let (pp, qq) = (16usize, 16usize);

    // Matched FLOP pruning rate ~3x for every scheme: 9 of 27 taps per
    // kernel (KGS / Pattern / BlockPunched), 5 of 16 channel groups for
    // Vanilla (3.2x — the closest its coarse unit reaches).
    let keep_locs = 9usize;
    let vanilla_keep = 5usize;
    let plans = [
        (
            "vanilla",
            compile_conv_sparse(
                &layer,
                &geom,
                &w,
                vec![0.0; m],
                &vanilla_mask(pp, qq, vanilla_keep),
                Scheme::Vanilla,
                4,
                4,
            ),
        ),
        (
            "kgs",
            compile_conv_sparse(
                &layer,
                &geom,
                &w,
                vec![0.0; m],
                &kgs_mask(pp, qq, keep_locs),
                Scheme::Kgs,
                4,
                4,
            ),
        ),
        (
            "pattern",
            compile_conv_sparse(
                &layer,
                &geom,
                &w,
                vec![0.0; m],
                &pattern_mask(m, ch, keep_locs),
                Scheme::Pattern,
                4,
                4,
            ),
        ),
        (
            "block_punched",
            compile_conv_sparse(
                &layer,
                &geom,
                &w,
                vec![0.0; m],
                &block_punched_mask(m, ch, keep_locs),
                Scheme::BlockPunched,
                4,
                4,
            ),
        ),
    ];

    // --- kernel-level frontier: one conv shape, four plans -------------
    let pt = executors::im2col_t(&x, &geom);
    let mut out = Mat::zeros(m, pt.cols);
    let mut group = BenchGroup::new("table3").budget(budget_from_env(3000));
    for (name, cc) in &plans {
        println!(
            "table3 {name}: rate={:.2}x kept_flops={}",
            1.0 / cc.density(),
            cc.flops
        );
        group.bench(name, || executors::run_compiled_conv(cc, &pt, &mut out));
    }
    let layer_stats: Vec<(String, f64, f64, f64)> = plans
        .iter()
        .map(|(name, cc)| {
            let s = group.median(name).unwrap();
            let gflops = cc.flops as f64 / s / 1e9;
            ((*name).to_string(), s * 1e3, gflops, 1.0 / cc.density())
        })
        .collect();

    // --- deployment-level frontier: synthetic end-to-end forwards ------
    // (Vanilla has no synthetic variant — layer-level row only.)
    let mut e2e = BenchGroup::new("table3-e2e").budget(budget_from_env(3000));
    let mut e2e_ms = Vec::new();
    for scheme in ["kgs", "pattern", "block_punched"] {
        let model = Model::synthetic_c3d_scheme(SyntheticC3d::default(), scheme);
        let input = model.manifest.input;
        let engine = NativeEngine::builder(&model).sparsity(true).build();
        let clip =
            Tensor5::random([1, input[0], input[1], input[2], input[3]], 7);
        let _warm = engine.forward(&clip); // size the arena before timing
        e2e.bench(scheme, || {
            let _ = engine.forward(&clip);
        });
        e2e_ms.push((scheme, e2e.median(scheme).unwrap() * 1e3));
    }

    for (name, ms, gflops, rate) in &layer_stats {
        println!("table3 {name}: {ms:.3} ms  {gflops:.2} GFLOP/s  ({rate:.2}x)");
    }
    for (name, ms) in &e2e_ms {
        println!("table3 {name} e2e: {ms:.3} ms");
    }

    // --- publish the frontier ------------------------------------------
    let frontier: Vec<String> = layer_stats
        .iter()
        .map(|(name, ms, gflops, rate)| {
            let e2e = e2e_ms
                .iter()
                .find(|(n, _)| *n == name.as_str())
                .map(|(_, v)| format!("{v:.4}"))
                .unwrap_or_else(|| "null".into());
            format!(
                concat!(
                    "    {{\"scheme\": \"{}\", \"rate\": {:.4}, ",
                    "\"layer_ms\": {:.4}, \"gflops\": {:.4}, ",
                    "\"e2e_ms\": {}}}"
                ),
                name, rate, ms, gflops, e2e
            )
        })
        .collect();
    let flat: String = layer_stats
        .iter()
        .map(|(name, ms, gflops, _)| {
            format!(
                "  \"{name}_ms\": {ms:.4},\n  \"{name}_gflops\": {gflops:.4},\n"
            )
        })
        .chain(
            e2e_ms
                .iter()
                .map(|(name, ms)| format!("  \"{name}_e2e_ms\": {ms:.4},\n")),
        )
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"table3\",\n  \"model\": \"conv m={m} c={ch} \
         [8,16,16] + c3d-synthetic e2e\",\n  \"threads\": {threads},\n\
         {flat}  \"frontier\": [\n{}\n  ]\n}}\n",
        frontier.join(",\n"),
    );
    let path = write_repo_json("BENCH_table3.json", &json);
    println!("table3 frontier written to {}", path.display());
}

//! E3 (paper Table 3): Vanilla vs KGS latency at matched accuracy.
//!
//! The accuracy matching comes from python (`compile/experiments/table1.py`
//! -> matched-rate pairs); here we measure the latency side at the paper's
//! matched rates: Vanilla 2.4x vs KGS 4.0x FLOPs reduction. Expected
//! shape: KGS at 4.0x is faster than Vanilla at 2.4x (Table 3's point).

use rt3d::codegen::{compile_conv_sparse, Scheme};
use rt3d::executors;
use rt3d::model::{ConvLayer, TensorRef, WeightRefs};
use rt3d::tensor::{Conv3dGeometry, Mat, Tensor5};
use rt3d::util::bench::BenchGroup;
use std::time::Duration;

fn conv(m: usize, c: usize) -> (ConvLayer, Conv3dGeometry) {
    let dummy = TensorRef { offset: 0, shape: vec![], dtype: "f32".into() };
    let layer = ConvLayer {
        name: "bench".into(),
        in_ch: c,
        out_ch: m,
        kernel: [3, 3, 3],
        stride: [1, 1, 1],
        padding: [1, 1, 1],
        relu: true,
        weights: WeightRefs { w: dummy.clone(), b: dummy },
        weights_sparse: None,
        unit_mask: None,
        quant: None,
    };
    let geom = Conv3dGeometry {
        in_ch: c,
        out_ch: m,
        kernel: [3, 3, 3],
        stride: [1, 1, 1],
        padding: [1, 1, 1],
        in_spatial: [8, 16, 16],
    };
    (layer, geom)
}

fn kgs_mask(pp: usize, qq: usize, keep: usize) -> Vec<bool> {
    let mut mask = vec![false; pp * qq * 27];
    for g in 0..pp * qq {
        for i in 0..keep {
            mask[g * 27 + (i * 5 + g) % 27] = true;
        }
    }
    mask
}

fn vanilla_mask(pp: usize, qq: usize, keep: usize) -> Vec<bool> {
    let mut mask = vec![false; pp * qq];
    for p in 0..pp {
        for i in 0..keep {
            mask[p * qq + (i * 3 + p) % qq] = true;
        }
    }
    mask
}

fn main() {
    println!(
        "table3: {} executor threads (RT3D_THREADS)",
        rt3d::util::pool::ThreadPool::global().threads()
    );
    let (m, ch) = (64usize, 64usize);
    let (layer, geom) = conv(m, ch);
    let w = Tensor5::random([m, ch, 3, 3, 3], 1).data;
    let x = Tensor5::random([1, ch, 8, 16, 16], 2);
    let (pp, qq) = (16usize, 16usize);

    // Paper Table 3 matched-accuracy configs: Vanilla ~2.4x vs KGS 4.0x.
    let vanilla_keep = (qq as f64 / 2.4).round() as usize; // ~7 of 16 groups
    let kgs_keep = (27f64 / 4.0).round() as usize; // ~7 of 27 locations
    let vanilla = compile_conv_sparse(
        &layer,
        &geom,
        &w,
        vec![0.0; m],
        &vanilla_mask(pp, qq, vanilla_keep),
        Scheme::Vanilla,
        4,
        4,
    );
    let kgs = compile_conv_sparse(
        &layer,
        &geom,
        &w,
        vec![0.0; m],
        &kgs_mask(pp, qq, kgs_keep),
        Scheme::Kgs,
        4,
        4,
    );
    println!(
        "table3 config: vanilla rate={:.2}x kgs rate={:.2}x",
        1.0 / vanilla.density(),
        1.0 / kgs.density()
    );
    let pt = executors::im2col_t(&x, &geom);
    let mut out = Mat::zeros(m, pt.cols);
    let mut group = BenchGroup::new("table3").budget(Duration::from_secs(3));
    group.bench("vanilla_2.4x", || {
        executors::run_compiled_conv(&vanilla, &pt, &mut out)
    });
    group.bench("kgs_4.0x", || {
        executors::run_compiled_conv(&kgs, &pt, &mut out)
    });
    let tv = group.median("vanilla_2.4x").unwrap();
    let tk = group.median("kgs_4.0x").unwrap();
    println!(
        "table3 verdict: kgs(4.0x) is {:.2}x faster than vanilla(2.4x) \
         at matched accuracy (paper: 525->329ms CPU, i.e. 1.6x)",
        tv / tk
    );
}

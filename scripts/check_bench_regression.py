#!/usr/bin/env python3
"""Compare a bench JSON against a committed baseline, or rebuild the
baseline from measured runs.

Usage:
  check_bench_regression.py <baseline.json> <current.json>
  check_bench_regression.py --update-baseline <out.json> <run.json> [...]

Gate mode (two paths): emit a GitHub Actions warning when a latency
metric degrades beyond the baseline tolerance (default 1.20x; the
baseline's own "tolerance" field overrides it — the committed baseline
carries 1.5x until CI variance data justifies tightening further).
Latency keys (p50_ms/p95_ms) warn when current/baseline exceeds the
tolerance; throughput keys (saturation_clips_per_s) warn when
baseline/current exceeds it. Never fails the build — CI runners are too
noisy to gate merges on wall-clock numbers; the warning plus the
uploaded artifact is the tracking signal. A baseline with null metrics
means "not seeded yet" and skips the comparison. When the
GITHUB_STEP_SUMMARY environment variable is set (any GitHub Actions
step), a per-key markdown table of the comparison is appended to the
job summary page.

Update mode (--update-baseline): take one or more BENCH_serving.json
files from repeated bench runs and write their per-key median as the new
baseline (the `bench-baseline` workflow_dispatch job in ci.yml runs the
bench several times, calls this, and uploads the result as an artifact
for a baseline-refresh PR).
"""

import json
import os
import sys

THRESHOLD = 1.20  # warn when a metric degrades past 120% of baseline
UPDATE_TOLERANCE = 1.5  # tolerance stamped into refreshed baselines

# Latency-style keys: larger is worse. The *_peak_scratch_mb keys are the
# gemm-kernels bench's measured scratch high-water marks — deterministic
# for a given thread count, so a growth past tolerance means the fused
# path's working set regressed (e.g. panel slabs started scaling with R).
# shed_rate/failed_rate come from the serving bench's overload section:
# shed_rate is bounded by 1.0 (so with the committed 0.7 baseline at 1.5x
# tolerance it can never warn spuriously — it is tracking data), and
# failed_rate's baseline of 0.0 skips the ratio check by design; a
# fault-free serving bench asserts failed_rate == 0 itself. The fleet_*
# keys come from the fleet bench (open-loop bursty replay through the
# 2-worker supervisor, scheduled-arrival latency — BENCH_fleet.json);
# fleet_shed_rate's 0.0 baseline likewise skips the ratio check, and the
# fleet bench itself asserts lost == unanswered == failed == 0.
LATENCY_KEYS = ("p95_ms", "p50_ms", "p95_ms_1t", "p50_ms_1t",
                "fused_peak_scratch_mb", "materialized_peak_scratch_mb",
                "shed_rate", "failed_rate", "net_p95_ms",
                "fleet_p50_ms", "fleet_p99_ms", "fleet_p999_ms",
                "fleet_shed_rate",
                # table3 four-scheme frontier: per-scheme single-layer
                # latency at matched ~3x FLOP rates, plus end-to-end
                # synthetic-C3D forward latency for the schemes that have
                # artifact-free synthetic variants (Vanilla is layer-level
                # only). BENCH_table3.json.
                "vanilla_ms", "kgs_ms", "pattern_ms", "block_punched_ms",
                "kgs_e2e_ms", "pattern_e2e_ms", "block_punched_e2e_ms")
# Throughput-style keys: smaller is worse. The int8 keys gate the
# quantized GEMM path: int8_best_gflops is its raw throughput and
# int8_speedup_vs_f32 its advantage over the f32 SIMD kernels — the
# acceptance criterion for the quantized path is that it stays > 1.0.
# The net_* keys come from the serving bench's TCP-loopback section and
# track what the wire front door adds on top of the in-process pipeline.
THROUGHPUT_KEYS = ("saturation_clips_per_s", "fused_best_gflops",
                   "int8_best_gflops", "int8_speedup_vs_f32",
                   "net_clips_per_s",
                   # table3 per-scheme effective GFLOP/s (kept FLOPs over
                   # median layer latency) — the throughput side of the
                   # four-scheme frontier.
                   "vanilla_gflops", "kgs_gflops", "pattern_gflops",
                   "block_punched_gflops")
# Context carried into a refreshed baseline from the first run.
CONTEXT_KEYS = ("bench", "model", "threads", "isa_detected", "kernel",
                "simd_lanes", "workers_best", "workers", "sessions",
                "rate_hz", "modulation")


def load(path):
    with open(path) as f:
        return json.load(f)


def median(values):
    values = sorted(values)
    n = len(values)
    if n % 2 == 1:
        return values[n // 2]
    return (values[n // 2 - 1] + values[n // 2]) / 2.0


def update_baseline(out_path, run_paths) -> int:
    runs = []
    for path in run_paths:
        try:
            runs.append(load(path))
        except (FileNotFoundError, json.JSONDecodeError) as e:
            print(f"skipping unreadable run {path}: {e}")
    if not runs:
        print("no readable runs; baseline not written")
        return 1
    baseline = {
        "comment": (
            f"Measured baseline: per-key median over {len(runs)} serving "
            "bench run(s). Refresh via the bench-baseline "
            "workflow_dispatch job in ci.yml (runs the bench repeatedly, "
            "re-runs this script, and uploads the result for a "
            "baseline-refresh PR)."
        ),
        "tolerance": UPDATE_TOLERANCE,
        "runs": len(runs),
    }
    for key in CONTEXT_KEYS:
        if key in runs[0]:
            baseline[key] = runs[0][key]
    for key in LATENCY_KEYS + THROUGHPUT_KEYS + ("speedup_vs_1t",
                                                 "workers_speedup", "gflops",
                                                 "materialized_best_gflops"):
        values = [r[key] for r in runs
                  if isinstance(r.get(key), (int, float))]
        if values:
            baseline[key] = round(median(values), 4)
    with open(out_path, "w") as f:
        json.dump(baseline, f, indent=2)
        f.write("\n")
    print(f"wrote baseline {out_path} from {len(runs)} run(s): "
          + ", ".join(f"{k}={baseline[k]}" for k in LATENCY_KEYS + THROUGHPUT_KEYS
                      if k in baseline))
    return 0


def check(baseline_path, current_path) -> int:
    try:
        baseline = load(baseline_path)
    except FileNotFoundError:
        print(f"no baseline at {baseline_path}; skipping regression check")
        return 0
    except json.JSONDecodeError as e:
        print(f"::warning title=bench regression::cannot parse baseline "
              f"{baseline_path}: {e}")
        return 0
    try:
        current = load(current_path)
    except (FileNotFoundError, json.JSONDecodeError) as e:
        # Warning-only policy: a missing/truncated bench artifact should
        # surface loudly but never hard-fail the job.
        print(f"::warning title=bench regression::cannot read {current_path}: {e}")
        return 0

    threshold = baseline.get("tolerance", THRESHOLD)
    if not isinstance(threshold, (int, float)) or threshold <= 1.0:
        threshold = THRESHOLD

    checked = False
    rows = []  # (key, base, cur, current/baseline, warned)
    for key in LATENCY_KEYS + THROUGHPUT_KEYS:
        base, cur = baseline.get(key), current.get(key)
        if not isinstance(base, (int, float)) or base <= 0:
            continue
        if not isinstance(cur, (int, float)) or cur <= 0:
            continue
        checked = True
        # Degradation ratio, oriented so >1 is always "worse".
        if key in THROUGHPUT_KEYS:
            ratio = base / cur
            line = (f"{key}: baseline={base:.2f} current={cur:.2f} "
                    f"({cur / base:.0%} of baseline)")
        else:
            ratio = cur / base
            # Latency-style keys carry their unit in the name (ms / mb).
            line = (
                f"{key}: baseline={base:.2f} current={cur:.2f} "
                f"({ratio:.0%} of baseline, threads base={baseline.get('threads')} "
                f"cur={current.get('threads')})"
            )
        warned = ratio > threshold
        rows.append((key, base, cur, cur / base, warned))
        if warned:
            # GitHub Actions warning annotation; does not fail the job.
            print(f"::warning title=bench regression::{line} exceeds "
                  f"{threshold:.2f}x baseline")
        else:
            print(f"ok {line}")
    if not checked:
        print("baseline not seeded yet (null metrics); refresh it with the "
              "bench-baseline workflow_dispatch job (--update-baseline)")
    write_step_summary(current.get("bench", current_path), threshold, rows)
    return 0


def write_step_summary(bench, threshold, rows):
    """Append a per-key markdown table to the GitHub job summary page."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path or not rows:
        return
    try:
        with open(path, "a") as f:
            f.write(f"### Bench regression check: `{bench}`\n\n")
            f.write(f"Warning threshold {threshold:.2f}x baseline; "
                    "warning-only (never fails the job). For throughput "
                    "keys, under 100% of baseline is slower; for latency "
                    "keys, over 100% is slower.\n\n")
            f.write("| key | baseline | current | current/baseline | status |\n")
            f.write("|-----|---------:|--------:|-----------------:|--------|\n")
            for key, base, cur, pct, warned in rows:
                status = "regressed" if warned else "ok"
                f.write(f"| `{key}` | {base:.2f} | {cur:.2f} | {pct:.0%} "
                        f"| {status} |\n")
            f.write("\n")
    except OSError as e:
        print(f"could not append to GITHUB_STEP_SUMMARY: {e}")


def main() -> int:
    args = sys.argv[1:]
    if len(args) >= 2 and args[0] == "--update-baseline":
        return update_baseline(args[1], args[2:])
    if len(args) != 2:
        print(__doc__)
        return 2
    return check(args[0], args[1])


if __name__ == "__main__":
    sys.exit(main())

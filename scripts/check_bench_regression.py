#!/usr/bin/env python3
"""Compare a bench JSON against a committed baseline (warning-only).

Usage: check_bench_regression.py <baseline.json> <current.json>

Policy (ROADMAP "Open items" / SNIPPETS §2 pattern): emit a GitHub Actions
warning when p95 latency degrades by more than 20% vs the committed
baseline. Never fails the build — CI runners are too noisy to gate merges
on wall-clock numbers; the warning plus the uploaded artifact is the
tracking signal. A baseline with null metrics means "not seeded yet" and
skips the comparison; a baseline carrying a "tolerance" field (used while
the committed numbers are machine-independent estimates rather than a
measured CI run) overrides the default 1.20 ratio.
"""

import json
import sys

THRESHOLD = 1.20  # warn when current p95 > 120% of baseline


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    baseline_path, current_path = sys.argv[1], sys.argv[2]
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"no baseline at {baseline_path}; skipping regression check")
        return 0
    except json.JSONDecodeError as e:
        print(f"::warning title=bench regression::cannot parse baseline "
              f"{baseline_path}: {e}")
        return 0
    try:
        with open(current_path) as f:
            current = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError) as e:
        # Warning-only policy: a missing/truncated bench artifact should
        # surface loudly but never hard-fail the job.
        print(f"::warning title=bench regression::cannot read {current_path}: {e}")
        return 0

    threshold = baseline.get("tolerance", THRESHOLD)
    if not isinstance(threshold, (int, float)) or threshold <= 1.0:
        threshold = THRESHOLD
    if baseline.get("estimated"):
        print(f"baseline is an estimate; using tolerance {threshold:.2f}x "
              "(replace with a measured CI run to tighten the gate)")

    checked = False
    for key in ("p95_ms", "p50_ms"):
        base, cur = baseline.get(key), current.get(key)
        if not isinstance(base, (int, float)) or base <= 0:
            continue
        if not isinstance(cur, (int, float)):
            continue
        checked = True
        ratio = cur / base
        line = (
            f"{key}: baseline={base:.2f}ms current={cur:.2f}ms "
            f"({ratio:.0%} of baseline, threads base={baseline.get('threads')} "
            f"cur={current.get('threads')})"
        )
        if ratio > threshold:
            # GitHub Actions warning annotation; does not fail the job.
            print(f"::warning title=bench regression::{line} exceeds "
                  f"{threshold:.2f}x baseline")
        else:
            print(f"ok {line}")
    if not checked:
        print("baseline not seeded yet (null metrics); update "
              "rust/benches/baseline/BENCH_serving.json from a stabilized run")
    return 0


if __name__ == "__main__":
    sys.exit(main())

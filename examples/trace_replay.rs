//! Open-loop trace-replay driver for a live serving endpoint — the CI
//! `fleet-e2e` job points this at a background `rt3d fleet` supervisor
//! (it works identically against a single `rt3d serve --listen` worker).
//!
//! Replays a seeded Poisson trace, optionally shaped bursty or diurnal,
//! over several persistent connections with a mixed fresh-clip /
//! windowed-stream request pattern (see `rt3d::workload::replay`), then
//! enforces the serving contract and prints the latency tail:
//!
//! * normal mode — every request sent, nothing lost, nothing skipped,
//!   no failed responses;
//! * `--expect-kill` — a worker is being killed mid-run: connections
//!   through it may die (`lost`/`skipped` > 0 allowed), but surviving
//!   connections must still be answered exactly-once (`unanswered` must
//!   be 0 in every mode) and some requests must succeed.
//!
//! ```sh
//! rt3d fleet -n 2 --listen 127.0.0.1:4071 --allow-shutdown &
//! cargo run --release --example trace_replay -- \
//!     --addr 127.0.0.1:4071 [--rate 40] [--requests 200] [--sessions 4] \
//!     [--burst PERIOD:DUTY:FACTOR | --diurnal PERIOD:AMP] [--seed 1] \
//!     [--frames D] [--size S] [--expect-kill] [--scrape] [--shutdown]
//! ```

use rt3d::coordinator::net::fetch_metrics;
use rt3d::coordinator::{Frame, NetClient};
use rt3d::model::SyntheticC3d;
use rt3d::util::args::Args;
use rt3d::workload::{replay, Modulation, ReplayConfig};

/// `--burst P:D:F` / `--diurnal P:A` → a [`Modulation`].
fn parse_modulation(args: &Args) -> rt3d::Result<Modulation> {
    if let Some(spec) = args.get("burst") {
        let parts: Vec<&str> = spec.split(':').collect();
        let [p, d, f] = parts.as_slice() else {
            rt3d::bail!("--burst wants PERIOD_S:DUTY:FACTOR, got {spec:?}");
        };
        return Ok(Modulation::Bursty {
            period_s: p.parse().map_err(|e| rt3d::anyhow!("bad burst period: {e}"))?,
            duty: d.parse().map_err(|e| rt3d::anyhow!("bad burst duty: {e}"))?,
            factor: f.parse().map_err(|e| rt3d::anyhow!("bad burst factor: {e}"))?,
        });
    }
    if let Some(spec) = args.get("diurnal") {
        let Some((p, a)) = spec.split_once(':') else {
            rt3d::bail!("--diurnal wants PERIOD_S:AMPLITUDE, got {spec:?}");
        };
        return Ok(Modulation::Diurnal {
            period_s: p.parse().map_err(|e| rt3d::anyhow!("bad diurnal period: {e}"))?,
            amplitude: a.parse().map_err(|e| rt3d::anyhow!("bad diurnal amplitude: {e}"))?,
        });
    }
    Ok(Modulation::None)
}

fn main() -> rt3d::Result<()> {
    let args = Args::parse_env();
    let addr = args.get_or("addr", "127.0.0.1:4071");
    let synth = SyntheticC3d::default();
    let cfg = ReplayConfig {
        model: args.get_or("model", "c3d"),
        rate_hz: args.get_f64("rate", 40.0),
        requests: args.get_usize("requests", 200),
        seed: args.get_usize("seed", 1) as u64,
        modulation: parse_modulation(&args)?,
        sessions: args.get_usize("sessions", 4),
        frames: args.get_usize("frames", synth.frames),
        size: args.get_usize("size", synth.size),
        deadline_ms: args.get_usize("deadline-ms", 0) as u32,
        ..ReplayConfig::new(addr.clone())
    };
    let expect_kill = args.flag("expect-kill");

    println!(
        "trace_replay: {} requests at {} req/s over {} sessions -> {addr} ({:?})",
        cfg.requests, cfg.rate_hz, cfg.sessions, cfg.modulation
    );
    let r = replay(&cfg)?;
    println!(
        "trace_replay: sent={} skipped={} ok={} failed={} shed={} deadline={} lost={} unanswered={}",
        r.sent, r.skipped, r.ok, r.failed, r.shed, r.deadline_miss, r.lost, r.unanswered
    );
    println!(
        "trace_replay: p50={:.1}ms p99={:.1}ms p99.9={:.1}ms max={:.1}ms shed_rate={:.3} offered={:.1}/s achieved={:.1}/s wall={:.1}s",
        r.p50_ms, r.p99_ms, r.p999_ms, r.max_ms, r.shed_rate,
        r.offered_rate_hz, r.achieved_rate_hz, r.wall_s
    );

    // Exactly-one-response on a cleanly closed connection is the wire
    // contract — no mode relaxes it.
    if r.unanswered > 0 {
        rt3d::bail!("{} responses missing on cleanly-closed connections", r.unanswered);
    }
    if r.ok == 0 {
        rt3d::bail!("no request executed successfully");
    }
    if expect_kill {
        // The killed worker's connections legitimately drop work; the
        // supervisor must keep the rest of the fleet serving.
        println!("trace_replay: --expect-kill: {} lost / {} skipped tolerated", r.lost, r.skipped);
    } else {
        if r.lost > 0 || r.skipped > 0 {
            rt3d::bail!(
                "lost {} / skipped {} requests without --expect-kill",
                r.lost,
                r.skipped
            );
        }
        if r.failed > 0 {
            rt3d::bail!("{} failed responses in a fault-free run", r.failed);
        }
    }

    if args.flag("scrape") {
        let metrics = fetch_metrics(addr.as_str())?;
        println!("--- GET /metrics ---");
        print!("{metrics}");
        println!("--- end /metrics ---");
    }

    if args.flag("shutdown") {
        let mut client = NetClient::connect(addr.as_str())?;
        client.send(&Frame::Shutdown)?;
        match client.recv()? {
            Frame::Bye => println!("trace_replay: endpoint acknowledged shutdown"),
            other => rt3d::bail!("expected Bye after Shutdown, got {other:?}"),
        }
    }
    Ok(())
}

//! Quickstart: build an engine through the one front door
//! (`NativeEngine::builder`), run one clip through the execution paths
//! (native RT3D dense, sparse, and — with `--features pjrt` — the
//! PJRT-compiled HLO), and print the predictions.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! # or, with no artifacts, against the in-memory synthetic C3D model:
//! cargo run --release --example quickstart
//! ```
//!
//! Every knob resolves **builder > RT3D_* env > tuned/heuristic default**
//! (run `rt3d env` to see the environment layer).

use rt3d::executors::NativeEngine;
use rt3d::model::{Model, SyntheticC3d};
use rt3d::workload;

fn main() -> rt3d::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let model = if std::path::Path::new(&dir).join("c3d.manifest.json").exists() {
        Model::load(&dir, "c3d")?
    } else {
        println!("quickstart: artifacts missing — using the synthetic C3D model");
        Model::synthetic_c3d(SyntheticC3d::default())
    };
    let input = model.manifest.input;
    println!(
        "loaded {}: input={:?}, dense {:.2} GFLOPs/clip",
        model.manifest.model,
        input,
        model.manifest.flops_dense as f64 / 1e9
    );

    // A labelled synthetic clip (class 4 = clockwise rotation).
    let label = 4;
    let clip = workload::make_clip(label, 7, input[1], input[2]);

    // Path 1: native RT3D executors (dense plans). The builder is the
    // whole configuration surface: unset knobs fall through to the
    // RT3D_* environment, then to the tuned/heuristic defaults.
    let engine = NativeEngine::builder(&model).build();
    let t0 = std::time::Instant::now();
    let logits = engine.forward(&clip);
    println!(
        "native rt3d: {:?} -> predicted class {} ({:.1} ms, {} threads)",
        &logits.row(0)[..model.manifest.num_classes.min(4)],
        argmax(logits.row(0)),
        t0.elapsed().as_secs_f64() * 1e3,
        engine.threads()
    );

    // Path 2: the AOT-compiled HLO through PJRT (three-layer path). Only
    // built with `--features pjrt` — the xla crate is not vendored.
    #[cfg(feature = "pjrt")]
    {
        let rt = rt3d::runtime::Runtime::cpu()?;
        println!("PJRT platform: {}", rt.platform());
        let exe = rt.load(
            model.hlo_path("dense_xla_b1").expect("artifact missing"),
            [1, input[0], input[1], input[2], input[3]],
        )?;
        println!("compiled dense_xla_b1 in {:.2}s", exe.compile_time_s);
        let t0 = std::time::Instant::now();
        let pjrt_logits = exe.run(&clip.data)?;
        println!(
            "pjrt xla:    {:?} -> predicted class {} ({:.1} ms)",
            &pjrt_logits[..model.manifest.num_classes.min(4)],
            argmax(&pjrt_logits),
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt xla:    skipped (build with --features pjrt to enable)");

    // Path 3: sparse (pruned) plans — same prediction, fewer FLOPs. An
    // explicit thread count overrides RT3D_THREADS; everything else stays
    // on its default.
    let sparse = NativeEngine::builder(&model).sparsity(true).threads(2).build();
    let t0 = std::time::Instant::now();
    let slogits = sparse.forward(&clip);
    println!(
        "native kgs:  {:?} -> predicted class {} ({:.1} ms, {:.2} GFLOPs)",
        &slogits.row(0)[..model.manifest.num_classes.min(4)],
        argmax(slogits.row(0)),
        t0.elapsed().as_secs_f64() * 1e3,
        sparse.conv_flops() as f64 / 1e9
    );
    println!("true label: {label}");
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

//! Wire client for a live `rt3d serve --listen` server — the driver the
//! CI `serve-e2e` job points at a background server. Speaks the binary
//! frame protocol (crate docs, "Wire protocol"): streams labelled clips
//! (some with deliberately tight deadlines), optionally triggers one hot
//! model swap mid-stream, scrapes `GET /metrics`, and exits non-zero when
//! any invariant breaks — every submitted id answered exactly once, no
//! failed windows in normal mode, injected panics surfaced (and survived)
//! in `--expect-panics` mode.
//!
//! ```sh
//! rt3d serve --listen 127.0.0.1:4070 --allow-shutdown &
//! cargo run --release --example net_client -- \
//!     --addr 127.0.0.1:4070 [--clips 32] [--model c3d] \
//!     [--swap] [--expect-panics] [--shutdown] [--frames D] [--size S]
//! ```
//!
//! Clip geometry defaults to the synthetic C3D model the server falls
//! back to without artifacts; pass `--frames/--size` when the server
//! loaded real artifacts with a different input shape.

use rt3d::coordinator::net::fetch_metrics;
use rt3d::coordinator::{Frame, NetClient, Outcome};
use rt3d::model::SyntheticC3d;
use rt3d::util::args::Args;
use rt3d::workload;
use std::collections::HashSet;

#[derive(Default)]
struct Tally {
    ok: usize,
    failed: usize,
    shed: usize,
    deadline: usize,
}

impl Tally {
    fn add(&mut self, outcome: Outcome) {
        match outcome {
            Outcome::Ok => self.ok += 1,
            Outcome::Failed => self.failed += 1,
            Outcome::Shed => self.shed += 1,
            Outcome::DeadlineExceeded => self.deadline += 1,
        }
    }
}

/// Submit one deterministic labelled clip as request `id`.
fn submit(
    client: &mut NetClient,
    model: &str,
    id: u64,
    frames: usize,
    size: usize,
    deadline_ms: u32,
) -> rt3d::Result<()> {
    let label = (id as usize) % workload::NUM_CLASSES;
    let clip = workload::make_clip(label, 4242 + id, frames, size);
    client.request(id, model, clip, Some(label as u32), deadline_ms)
}

/// Read server frames until `want` response ids are answered (plus
/// `want_swaps` SwapDone verdicts), tallying outcomes. Errors on a
/// duplicate/unknown id, a failed swap, or a typed server error.
fn collect(
    client: &mut NetClient,
    expect: &mut HashSet<u64>,
    want: usize,
    want_swaps: usize,
    tally: &mut Tally,
) -> rt3d::Result<usize> {
    let mut responses = 0;
    let mut swaps = 0;
    while responses < want || swaps < want_swaps {
        match client.recv()? {
            Frame::Response { id, outcome, .. } => {
                if !expect.remove(&id) {
                    rt3d::bail!("duplicate or unknown response id {id}");
                }
                tally.add(outcome);
                responses += 1;
            }
            Frame::SwapDone { ok, msg } => {
                if !ok {
                    rt3d::bail!("hot swap failed: {msg}");
                }
                println!("net_client: {msg}");
                swaps += 1;
            }
            Frame::Error { code, msg } => {
                rt3d::bail!("server error (code {code}): {msg}")
            }
            other => rt3d::bail!("unexpected server frame {other:?}"),
        }
    }
    Ok(swaps)
}

fn main() -> rt3d::Result<()> {
    let args = Args::parse_env();
    let addr = args.get_or("addr", "127.0.0.1:4070");
    let model = args.get_or("model", "c3d");
    let clips = args.get_usize("clips", 32).max(2);
    let do_swap = args.flag("swap");
    let expect_panics = args.flag("expect-panics");
    let do_shutdown = args.flag("shutdown");
    let synth = SyntheticC3d::default();
    let frames = args.get_usize("frames", synth.frames);
    let size = args.get_usize("size", synth.size);

    let mut client = NetClient::connect(addr.as_str())?;
    let mut tally = Tally::default();
    let mut expect: HashSet<u64> = HashSet::new();
    let mut next_id: u64 = 0;
    let mut swaps = 0;

    // Main stream: half the clips, one optional hot swap, the other half.
    // Every 8th request carries a 1 ms deadline — tight enough that the
    // deadline path gets exercised without making the outcome count part
    // of the contract (a fast engine may legitimately beat it).
    let half = clips / 2;
    for phase in 0..2u32 {
        let n = if phase == 0 { half } else { clips - half };
        if phase == 1 && do_swap {
            // Empty dir = the server-side `--swap-artifacts` default.
            client.send(&Frame::Swap { model: model.clone(), dir: String::new() })?;
        }
        for _ in 0..n {
            let deadline_ms = u32::from(next_id % 8 == 3);
            submit(&mut client, &model, next_id, frames, size, deadline_ms)?;
            expect.insert(next_id);
            next_id += 1;
        }
    }
    swaps += collect(&mut client, &mut expect, clips, usize::from(do_swap), &mut tally)?;

    if expect_panics {
        // Fault mode (`RT3D_FAULTS=panic@p` on the server): keep streaming
        // bounded extra rounds until at least one injected panic surfaces
        // as a Failed response, then prove the server still serves.
        let mut rounds = 0;
        while tally.failed == 0 && rounds < 40 {
            rounds += 1;
            for _ in 0..8 {
                submit(&mut client, &model, next_id, frames, size, 0)?;
                expect.insert(next_id);
                next_id += 1;
            }
            collect(&mut client, &mut expect, 8, 0, &mut tally)?;
        }
        if tally.failed == 0 {
            rt3d::bail!("no injected panic surfaced after {rounds} extra rounds");
        }
        let before_ok = tally.ok;
        for _ in 0..4 {
            submit(&mut client, &model, next_id, frames, size, 0)?;
            expect.insert(next_id);
            next_id += 1;
        }
        collect(&mut client, &mut expect, 4, 0, &mut tally)?;
        if tally.ok <= before_ok {
            rt3d::bail!("server stopped serving Ok responses after injected panics");
        }
    } else if tally.failed > 0 {
        rt3d::bail!("{} failed windows in a fault-free run", tally.failed);
    }
    if tally.ok == 0 {
        rt3d::bail!("no request executed successfully");
    }
    if !expect.is_empty() {
        rt3d::bail!("{} submitted ids were never answered", expect.len());
    }

    // Scrape the Prometheus endpoint on the same listener; CI greps the
    // echoed body for the counter families.
    let metrics = fetch_metrics(addr.as_str())?;
    if !metrics.contains("rt3d_requests_total") {
        rt3d::bail!("/metrics is missing rt3d_requests_total:\n{metrics}");
    }
    println!("--- GET /metrics ---");
    print!("{metrics}");
    println!("--- end /metrics ---");

    if do_shutdown {
        client.send(&Frame::Shutdown)?;
        match client.recv()? {
            Frame::Bye => println!("net_client: server acknowledged shutdown"),
            other => rt3d::bail!("expected Bye after Shutdown, got {other:?}"),
        }
    }

    println!(
        "net_client: ok={} failed={} shed={} deadline_exceeded={} swaps={swaps}",
        tally.ok, tally.failed, tally.shed, tally.deadline
    );
    Ok(())
}

//! End-to-end serving driver (the E2E validation example from DESIGN.md):
//! loads the pruned C3D artifact, starts the coordinator (batcher + worker),
//! replays a Poisson trace of synthetic action clips, and reports latency,
//! throughput and *serving accuracy* against the known labels.
//!
//! ```sh
//! make artifacts && \
//!   cargo run --release --example serve_video [artifacts] [n_requests] [workers]
//! ```

use rt3d::coordinator::{BatcherConfig, Server, ServerConfig};
use rt3d::executors::{EngineKind, NativeEngine};
use rt3d::model::Model;
use rt3d::workload::{self, RequestTrace, TraceConfig};
use std::sync::Arc;

fn main() -> rt3d::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);
    let workers: usize = std::env::args()
        .nth(3)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let model = Model::load(&dir, "c3d")?;
    let input = model.manifest.input;

    for (label, sparse) in [("dense", false), ("kgs-sparse", true)] {
        let engine = Arc::new(NativeEngine::new(&model, EngineKind::Rt3d, sparse));
        println!(
            "\n== serving with {} engine ({:.2} GFLOPs/clip, {} workers)",
            label,
            engine.conv_flops() as f64 / 1e9,
            workers
        );
        let server = Server::start(
            engine,
            ServerConfig {
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: std::time::Duration::from_millis(15),
                },
                queue_depth: 64,
                workers,
            },
        );
        let responses = server.take_responses();
        let trace = RequestTrace::poisson(&TraceConfig {
            rate_hz: 30.0, // 30 requests/s ~ "real-time" per the paper
            count: n,
            seed: 99,
        });
        let t0 = std::time::Instant::now();
        let mut submitted = 0;
        for e in &trace.entries {
            // Pace submissions to the trace arrivals.
            let target = std::time::Duration::from_secs_f64(e.arrival_s);
            if let Some(sleep) = target.checked_sub(t0.elapsed()) {
                std::thread::sleep(sleep);
            }
            let clip =
                workload::make_clip(e.label, e.clip_seed, input[1], input[2]);
            server.submit(clip, Some(e.label))?;
            submitted += 1;
        }
        let mut done = 0;
        while done < submitted {
            responses.recv()?;
            done += 1;
        }
        let m = server.shutdown();
        let lat = m.latency();
        println!(
            "requests={} throughput={:.1} req/s mean_batch={:.2}",
            m.count(),
            m.throughput(),
            m.mean_batch()
        );
        println!(
            "latency ms: mean={:.1} p50={:.1} p95={:.1} p99={:.1} max={:.1}",
            lat.mean_s * 1e3,
            lat.p50_s * 1e3,
            lat.p95_s * 1e3,
            lat.p99_s * 1e3,
            lat.max_s * 1e3
        );
        if let Some(acc) = m.accuracy() {
            println!("serving accuracy: {:.3} (8 classes, chance 0.125)", acc);
        }
    }
    Ok(())
}

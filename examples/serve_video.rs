//! Streaming-video serving driver — the paper's actual mobile scenario:
//! frames arrive continuously from a "camera", a [`Session`] windows them
//! into 16-frame clips (configurable stride), the batched coordinator
//! pipeline executes them on the chosen backend, and per-window
//! predictions come back in stream order.
//!
//! ```sh
//! make artifacts && \
//!   cargo run --release --example serve_video [artifacts] [n_clips] [workers] [stride]
//! # with no artifacts the synthetic C3D model is used
//! ```

use rt3d::coordinator::{Server, ServerConfig, Session, SessionConfig};
use rt3d::executors::NativeEngine;
use rt3d::model::{Model, SyntheticC3d};
use rt3d::workload;
use std::sync::Arc;

fn main() -> rt3d::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n_clips: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let workers: usize = std::env::args()
        .nth(3)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let model = if std::path::Path::new(&dir).join("c3d.manifest.json").exists() {
        Model::load(&dir, "c3d")?
    } else {
        println!("serve_video: artifacts missing — using the synthetic C3D model");
        Model::synthetic_c3d(SyntheticC3d::default())
    };
    let input = model.manifest.input;

    // One front door: the builder resolves builder > RT3D_* env > tuned
    // defaults; the server takes its config by value.
    let engine = Arc::new(NativeEngine::builder(&model).sparsity(true).build());
    let server = Server::start(
        engine.clone(),
        ServerConfig::new()
            .max_batch(4)
            .max_wait(std::time::Duration::from_millis(15))
            .queue_depth(64)
            .workers(workers),
    );

    // The session's window/frame shape comes from the backend's model
    // geometry; stride defaults to the window (back-to-back clips). A
    // smaller stride overlaps windows (denser labels, more compute).
    let mut cfg = SessionConfig::for_backend(engine.as_ref())?;
    if let Some(stride) = std::env::args().nth(4).and_then(|s| s.parse().ok()) {
        cfg = cfg.stride(stride);
    }
    println!(
        "streaming session: frames {:?}, window {}, stride {}, {} workers x {} threads",
        cfg.frame_dims, cfg.window, cfg.stride, workers, engine.threads()
    );
    let mut session = Session::new(&server, cfg)?;

    // The "camera": n_clips labelled synthetic action clips played
    // back-to-back as one continuous frame stream. With stride = window,
    // window w sees exactly clip w, so the known labels score the
    // streaming pipeline end to end.
    let stride_tiles = session.config().stride == session.config().window;
    let mut labels = Vec::new();
    let mut tally = Tally::default();
    let t0 = std::time::Instant::now();
    for i in 0..n_clips {
        let label = i % workload::NUM_CLASSES;
        labels.push(label);
        let clip = workload::make_clip(label, 1000 + i as u64, input[1], input[2]);
        session.push_clip(&clip)?;
        // Results stream back while the camera keeps rolling. A failed
        // window (`try_next` yields `Some(Err(..))`) aborts this driver;
        // long-lived deployments would log it and keep streaming.
        while let Some(win) = session.try_next() {
            tally.report(&win?, &labels, stride_tiles);
        }
    }
    println!(
        "pushed {} frames -> {} windows in {:.2}s",
        session.frames_seen(),
        session.windows_submitted(),
        t0.elapsed().as_secs_f64()
    );

    // End of stream: drain the in-flight windows in order.
    for win in session.finish()? {
        tally.report(&win, &labels, stride_tiles);
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    println!(
        "\nserved {} windows in {:.2}s ({:.1} windows/s, mean batch {:.2})",
        m.count(),
        wall,
        m.count() as f64 / wall,
        m.mean_batch()
    );
    if stride_tiles && tally.total > 0 {
        println!(
            "streaming accuracy: {}/{} (chance {:.3}), mean latency {:.1} ms",
            tally.correct,
            tally.total,
            1.0 / workload::NUM_CLASSES as f64,
            1e3 * tally.latency_sum / tally.total as f64
        );
    }
    Ok(())
}

/// Per-window reporting + accuracy/latency accounting.
#[derive(Default)]
struct Tally {
    correct: usize,
    total: usize,
    latency_sum: f64,
}

impl Tally {
    fn report(
        &mut self,
        win: &rt3d::coordinator::WindowResult,
        labels: &[usize],
        tiled: bool,
    ) {
        self.total += 1;
        self.latency_sum += win.latency_s;
        let truth = if tiled {
            if labels.get(win.window) == Some(&win.predicted) {
                self.correct += 1;
            }
            labels
                .get(win.window)
                .map(|l| format!(" (true {l})"))
                .unwrap_or_default()
        } else {
            String::new()
        };
        println!(
            "window {:>3} [frames {:>4}..]: class {}{} {:.1} ms",
            win.window,
            win.first_frame,
            win.predicted,
            truth,
            win.latency_s * 1e3
        );
    }
}

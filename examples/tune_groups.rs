//! E7 driver: sweep kernel-group sizes (g_M x g_N) on a synthesized conv
//! layer, reproducing the paper's offline group-size selection (§3: g_N=4,
//! g_M=4 or 8 "preferred to match the SIMD parallelism").
//!
//! ```sh
//! cargo run --release --example tune_groups
//! ```

use rt3d::codegen::tuner::time_group_size;

fn main() {
    println!("KGS layer 64x64x(8,16,16), 3x FLOPs pruning, per group size:");
    println!("{:>8} {:>12} {:>14}", "g_MxG_N", "latency ms", "flops frac");
    let mut best: Option<(f64, (usize, usize))> = None;
    for (g_m, g_n) in [
        (2usize, 2usize),
        (2, 4),
        (4, 2),
        (4, 4),
        (8, 4),
        (4, 8),
        (8, 8),
        (16, 8),
        (16, 16),
    ] {
        let (secs, frac) =
            time_group_size(64, 64, [8, 16, 16], g_m, g_n, 1.0 / 3.0, 5);
        println!("{:>5}x{:<3} {:>10.2}ms {:>13.3}", g_m, g_n, secs * 1e3, frac);
        if best.map(|(b, _)| secs < b).unwrap_or(true) {
            best = Some((secs, (g_m, g_n)));
        }
    }
    if let Some((secs, (g_m, g_n))) = best {
        println!(
            "\nbest: {g_m}x{g_n} at {:.2} ms — paper prefers 4x4 / 8x4; larger \
             groups stop helping speed while costing accuracy (Table 1 side)",
            secs * 1e3
        );
    }
}

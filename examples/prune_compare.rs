//! Compare sparsity schemes end-to-end on the host executors and the
//! mobile cost model: the Table 2/3 story in one program.
//!
//! ```sh
//! make artifacts && cargo run --release --example prune_compare
//! ```

use rt3d::codegen;
use rt3d::device::{self, DeviceProfile, ExecutorClass};
use rt3d::executors::{EngineKind, NativeEngine};
use rt3d::model::Model;
use rt3d::tensor::Tensor5;

fn median_time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    let mut ts: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ts[ts.len() / 2]
}

fn main() -> rt3d::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!(
        "{:<10} {:<12} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "model", "engine", "host ms", "speedup", "GFLOPs", "simCPU ms", "simGPU ms"
    );
    for name in ["c3d", "r2plus1d", "s3d"] {
        let Ok(model) = Model::load(&dir, name) else { continue };
        let input = model.manifest.input;
        let clip =
            Tensor5::random([1, input[0], input[1], input[2], input[3]], 3);
        let cpu = DeviceProfile::mobile_cpu();
        let gpu = DeviceProfile::mobile_gpu();
        let mut base = None;
        for (label, kind, sparse) in [
            ("naive", EngineKind::Naive, false),
            ("untuned", EngineKind::Untuned, false),
            ("rt3d-dense", EngineKind::Rt3d, false),
            ("rt3d-kgs", EngineKind::Rt3d, true),
        ] {
            let engine =
                NativeEngine::builder(&model).kind(kind).sparsity(sparse).build();
            let reps = if kind == EngineKind::Naive { 1 } else { 3 };
            let t = median_time(|| { engine.forward(&clip); }, reps);
            let convs = codegen::compile_model(&model, sparse);
            let class = match kind {
                EngineKind::Naive => ExecutorClass::Naive,
                EngineKind::Untuned => ExecutorClass::Untuned,
                EngineKind::Rt3d => ExecutorClass::Rt3d,
            };
            let (sc, _) = device::model_cost(&convs, class, &cpu, 1);
            let (sg, _) = device::model_cost(&convs, class, &gpu, 1);
            let b = *base.get_or_insert(t);
            println!(
                "{:<10} {:<12} {:>9.1} {:>9.1}x {:>10.2} {:>11.1} {:>11.1}",
                name,
                label,
                t * 1e3,
                b / t,
                engine.conv_flops() as f64 / 1e9,
                sc * 1e3,
                sg * 1e3
            );
        }
    }
    println!("\n(speedup columns relative to the naive PyTorch-Mobile-class baseline,");
    println!(" matching the speedup columns of paper Table 2)");
    Ok(())
}
